//! Overload protection / flow control (Algorithm 2, phase 3).
//!
//! When PBAA reports requests that exceeded `N_limit` waiting cycles, the
//! flow controller decides between throttling (shed a fraction of new
//! admissions for a cool-down window) and outright rejection, and exposes
//! an admission check for the frontend.

use super::types::{Request, SloClass};

/// Flow-control policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowPolicy {
    /// Reject the overloaded requests themselves, admit everything else.
    RejectOverloaded,
    /// Additionally shed a fraction of *new* admissions for a cool-down
    /// period after each overload event (paper's "Throttle").
    Throttle,
}

/// Flow controller state.
#[derive(Debug, Clone)]
pub struct FlowController {
    policy: FlowPolicy,
    /// Fraction of new requests shed while throttling (0..1).
    pub shed_fraction: f64,
    /// Cool-down duration in seconds after an overload event.
    pub cooldown: f64,
    throttle_until: f64,
    /// Monotone counter used to deterministically shed every k-th request.
    admit_counter: u64,
    /// Requests rejected because they exceeded `N_limit` waiting cycles
    /// (or hit the frontend's hard in-flight cap), per [`SloClass::rank`].
    rejected_overload: [u64; 3],
    /// New arrivals shed during a throttle cool-down, per
    /// [`SloClass::rank`]. `rejected_shed[Interactive]` is zero by
    /// construction — interactive traffic is never shed.
    rejected_shed: [u64; 3],
}

impl FlowController {
    /// New controller.
    pub fn new(policy: FlowPolicy) -> Self {
        FlowController {
            policy,
            shed_fraction: 0.25,
            cooldown: 2.0,
            throttle_until: -1.0,
            admit_counter: 0,
            rejected_overload: [0; 3],
            rejected_shed: [0; 3],
        }
    }

    /// Total requests rejected so far (overload + shed, all classes).
    pub fn rejected(&self) -> u64 {
        self.rejected_overload.iter().sum::<u64>() + self.rejected_shed.iter().sum::<u64>()
    }

    /// Overload rejections (`N_limit` / queue-full), per [`SloClass::rank`].
    pub fn rejected_overload(&self) -> [u64; 3] {
        self.rejected_overload
    }

    /// Throttle-window sheds, per [`SloClass::rank`].
    pub fn rejected_shed(&self) -> [u64; 3] {
        self.rejected_shed
    }

    /// Whether throttling is active at `now`.
    pub fn throttling(&self, now: f64) -> bool {
        self.policy == FlowPolicy::Throttle && now < self.throttle_until
    }

    /// Handle PBAA's overloaded set at time `now`; returns the requests to
    /// reject upstream (all of them, under both policies — they already
    /// waited `N_limit` cycles).
    pub fn on_overload(&mut self, now: f64, overloaded: Vec<Request>) -> Vec<Request> {
        if !overloaded.is_empty() && self.policy == FlowPolicy::Throttle {
            self.throttle_until = now + self.cooldown;
        }
        for r in &overloaded {
            self.rejected_overload[r.class.rank()] += 1;
        }
        overloaded
    }

    /// Admission check for a new arrival of `class` at `now`.
    /// Class-ordered shedding: while throttling, `Batch` arrivals are
    /// always shed and `Interactive` never is, so no interactive request
    /// can be refused while batch traffic is still being admitted.
    /// `Standard` keeps the deterministic every-⌈1/shed_fraction⌉-th rule.
    pub fn admit(&mut self, now: f64, class: SloClass) -> bool {
        if !self.throttling(now) {
            return true;
        }
        match class {
            SloClass::Interactive => true,
            SloClass::Batch => {
                self.rejected_shed[class.rank()] += 1;
                false
            }
            SloClass::Standard => {
                self.admit_counter += 1;
                let period = (1.0 / self.shed_fraction).round().max(1.0) as u64;
                if self.admit_counter % period == 0 {
                    self.rejected_shed[class.rank()] += 1;
                    false
                } else {
                    true
                }
            }
        }
    }
}

/// Outcome of a frontend admission check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Accept the request into the cluster.
    Admit,
    /// Refuse: the in-flight job count is at capacity (hard overload).
    RejectQueueFull,
    /// Refuse: shed during a post-overload throttle cool-down.
    Shed,
}

/// Frontend admission control: a bounded in-flight window wrapped around
/// the [`FlowController`]. This is what the serving frontend consults
/// *before* a request ever reaches the scheduler, so overload surfaces as
/// an immediate `BUSY` on the wire instead of unbounded queueing —
/// the same two-tier shape as PBAA's in-scheduler overload path (queue
/// pressure triggers an overload event; the flow controller then sheds a
/// fraction of *new* arrivals for a cool-down window).
#[derive(Debug, Clone)]
pub struct AdmissionController {
    flow: FlowController,
    /// Maximum jobs in flight (queued + executing) before hard rejection.
    pub max_inflight: u64,
}

impl AdmissionController {
    /// Controller admitting at most `max_inflight` concurrent jobs.
    pub fn new(policy: FlowPolicy, max_inflight: u64) -> Self {
        AdmissionController {
            flow: FlowController::new(policy),
            max_inflight: max_inflight.max(1),
        }
    }

    /// Tune the wrapped flow controller (shed fraction / cool-down).
    pub fn flow_mut(&mut self) -> &mut FlowController {
        &mut self.flow
    }

    /// Total requests refused so far (queue-full + shed).
    pub fn rejected(&self) -> u64 {
        self.flow.rejected()
    }

    /// Queue-full rejections, per [`SloClass::rank`].
    pub fn rejected_overload(&self) -> [u64; 3] {
        self.flow.rejected_overload()
    }

    /// Throttle-window sheds, per [`SloClass::rank`].
    pub fn rejected_shed(&self) -> [u64; 3] {
        self.flow.rejected_shed()
    }

    /// Whether the post-overload throttle window is active at `now`.
    pub fn throttling(&self, now: f64) -> bool {
        self.flow.throttling(now)
    }

    /// Decide admission for `request` given the current in-flight count.
    pub fn try_admit(&mut self, now: f64, inflight: u64, request: Request) -> AdmissionDecision {
        if inflight >= self.max_inflight {
            // The queue is full: reject this request and (under Throttle)
            // arm the cool-down so pressure is relieved proactively.
            self.flow.on_overload(now, vec![request]);
            return AdmissionDecision::RejectQueueFull;
        }
        if !self.flow.admit(now, request.class) {
            return AdmissionDecision::Shed;
        }
        AdmissionDecision::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(id: u64) -> Request {
        Request::new(id, 100, 10, 0.0)
    }

    #[test]
    fn reject_policy_never_throttles() {
        let mut f = FlowController::new(FlowPolicy::RejectOverloaded);
        let rejected = f.on_overload(1.0, vec![r(1), r(2)]);
        assert_eq!(rejected.len(), 2);
        assert_eq!(f.rejected(), 2);
        assert!(!f.throttling(1.1));
        assert!(f.admit(1.1, SloClass::Standard));
    }

    #[test]
    fn throttle_sheds_fraction_during_cooldown() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.shed_fraction = 0.5;
        f.on_overload(10.0, vec![r(1)]);
        assert!(f.throttling(10.5));
        let admitted = (0..10).filter(|_| f.admit(10.5, SloClass::Standard)).count();
        assert_eq!(admitted, 5, "50% shed");
        // After cooldown everything is admitted again.
        assert!(!f.throttling(12.5));
        assert!(f.admit(12.5, SloClass::Standard));
    }

    #[test]
    fn empty_overload_does_not_arm_throttle() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.on_overload(10.0, vec![]);
        assert!(!f.throttling(10.1));
    }

    #[test]
    fn throttle_window_expires_at_boundary() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.on_overload(5.0, vec![r(1)]);
        assert!(f.throttling(5.0 + f.cooldown - 1e-9));
        assert!(!f.throttling(5.0 + f.cooldown));
    }

    #[test]
    fn repeated_overload_extends_cooldown() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.on_overload(0.0, vec![r(1)]);
        // A second overload mid-window pushes the cool-down out.
        f.on_overload(1.5, vec![r(2)]);
        assert!(f.throttling(1.5 + f.cooldown - 1e-9));
        assert_eq!(f.rejected(), 2);
    }

    #[test]
    fn rejected_accumulates_overload_and_shed() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.shed_fraction = 0.5;
        f.on_overload(0.0, vec![r(1)]); // 1 overload rejection
        let shed = (0..10)
            .filter(|_| !f.admit(0.5, SloClass::Standard))
            .count() as u64;
        assert_eq!(shed, 5);
        assert_eq!(f.rejected(), 1 + shed);
        // The split counters attribute each side to the right bucket.
        assert_eq!(f.rejected_overload(), [0, 1, 0]);
        assert_eq!(f.rejected_shed(), [0, shed, 0]);
    }

    #[test]
    fn throttle_sheds_batch_before_standard_before_interactive() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.on_overload(0.0, vec![r(1)]);
        assert!(f.throttling(0.5));
        // Interactive is never shed, batch always is, standard partially.
        for i in 0..20 {
            assert!(f.admit(0.5, SloClass::Interactive), "interactive #{i} shed");
            assert!(!f.admit(0.5, SloClass::Batch), "batch #{i} admitted");
        }
        let std_admitted = (0..20)
            .filter(|_| f.admit(0.5, SloClass::Standard))
            .count();
        assert!(std_admitted > 0 && std_admitted < 20);
        assert_eq!(f.rejected_shed()[SloClass::Interactive.rank()], 0);
        assert_eq!(f.rejected_shed()[SloClass::Batch.rank()], 20);
        // Once the window expires, batch is admitted again.
        assert!(f.admit(0.0 + f.cooldown, SloClass::Batch));
    }

    #[test]
    fn overload_rejections_count_per_class() {
        let mut f = FlowController::new(FlowPolicy::RejectOverloaded);
        f.on_overload(
            0.0,
            vec![
                r(1).with_class(SloClass::Interactive),
                r(2),
                r(3).with_class(SloClass::Batch),
                r(4).with_class(SloClass::Batch),
            ],
        );
        assert_eq!(f.rejected_overload(), [1, 1, 2]);
        assert_eq!(f.rejected_shed(), [0, 0, 0]);
        assert_eq!(f.rejected(), 4);
    }

    #[test]
    fn admission_rejects_at_capacity_and_arms_throttle() {
        let mut a = AdmissionController::new(FlowPolicy::Throttle, 4);
        assert_eq!(a.try_admit(0.0, 0, r(1)), AdmissionDecision::Admit);
        assert_eq!(a.try_admit(0.0, 3, r(2)), AdmissionDecision::Admit);
        // At capacity: hard reject, cool-down armed.
        assert_eq!(a.try_admit(1.0, 4, r(3)), AdmissionDecision::RejectQueueFull);
        assert!(a.throttling(1.1));
        // Below capacity again, but inside the cool-down: sheds a fraction.
        let outcomes: Vec<AdmissionDecision> =
            (0..8).map(|i| a.try_admit(1.2, 0, r(10 + i))).collect();
        assert!(outcomes.contains(&AdmissionDecision::Shed));
        assert!(outcomes.contains(&AdmissionDecision::Admit));
        // After the cool-down everything is admitted again.
        let later = 1.0 + 10.0;
        assert!(!a.throttling(later));
        assert_eq!(a.try_admit(later, 0, r(99)), AdmissionDecision::Admit);
    }

    #[test]
    fn admission_never_sheds_interactive_while_admitting_batch() {
        let mut a = AdmissionController::new(FlowPolicy::Throttle, 4);
        assert_eq!(a.try_admit(0.0, 4, r(0)), AdmissionDecision::RejectQueueFull);
        assert!(a.throttling(0.1));
        let mut batch_shed = 0;
        for i in 0..16 {
            let interactive = r(100 + i).with_class(SloClass::Interactive);
            assert_eq!(a.try_admit(0.1, 0, interactive), AdmissionDecision::Admit);
            if a.try_admit(0.1, 0, r(200 + i).with_class(SloClass::Batch))
                == AdmissionDecision::Shed
            {
                batch_shed += 1;
            }
        }
        assert_eq!(batch_shed, 16, "all batch arrivals shed in the window");
        assert_eq!(a.rejected_shed(), [0, 0, 16]);
        assert_eq!(a.rejected_overload()[SloClass::Standard.rank()], 1);
    }

    #[test]
    fn admission_reject_policy_never_sheds() {
        let mut a = AdmissionController::new(FlowPolicy::RejectOverloaded, 2);
        assert_eq!(a.try_admit(0.0, 2, r(1)), AdmissionDecision::RejectQueueFull);
        for i in 0..20 {
            assert_eq!(a.try_admit(0.1, 0, r(2 + i)), AdmissionDecision::Admit);
        }
        assert_eq!(a.rejected(), 1);
    }
}
