//! The Global State Matrix (paper Fig. 5): real-time per-DP-unit state
//! vectors `⟨C_avail, B_i, K_i⟩` and per-instance readiness.
//!
//! §4.2.1 defines Real-time Available Capacity as
//! `C_avail = C_chunk − U_flight − R_queued`: the hardware chunk budget
//! minus tokens in transit (dispatched, unacknowledged) minus the backlog
//! already buffered on the device.

use super::types::DpUnitId;

/// Real-time state of one DP-Attention unit.
#[derive(Debug, Clone)]
pub struct DpState {
    /// Identity of this unit.
    pub id: DpUnitId,
    /// Hardware-constrained max token capacity per forward pass
    /// (`C_chunk`, e.g. 3072 for the paper's "3K chunk" config).
    pub c_chunk: u32,
    /// Tokens dispatched but not yet acknowledged (`U_flight`).
    pub u_flight: u32,
    /// Token backlog buffered on the device (`R_queued`).
    pub r_queued: u32,
    /// Decode batch size (`B_i`, Algorithm 3).
    pub batch: u32,
    /// Resident KV cache length in tokens (`K_i`, Algorithm 3).
    pub kv_tokens: u64,
}

impl DpState {
    /// Fresh idle unit.
    pub fn new(id: DpUnitId, c_chunk: u32) -> Self {
        DpState {
            id,
            c_chunk,
            u_flight: 0,
            r_queued: 0,
            batch: 0,
            kv_tokens: 0,
        }
    }

    /// §4.2.1: `C_avail = C_chunk − U_flight − R_queued`. May be negative
    /// when the device is oversubscribed (requests spanning chunks).
    pub fn c_avail(&self) -> i64 {
        self.c_chunk as i64 - self.u_flight as i64 - self.r_queued as i64
    }

    /// Account tokens dispatched toward this unit.
    pub fn on_dispatch(&mut self, tokens: u32) {
        self.u_flight += tokens;
    }

    /// Device acknowledged receipt: tokens move from flight to backlog.
    pub fn on_ack(&mut self, tokens: u32) {
        let t = tokens.min(self.u_flight);
        self.u_flight -= t;
        self.r_queued += t;
    }

    /// A forward pass consumed `tokens` from the backlog.
    pub fn on_consumed(&mut self, tokens: u32) {
        self.r_queued = self.r_queued.saturating_sub(tokens);
    }

    /// A decode request joined this unit (Algorithm 3 state update).
    pub fn on_decode_join(&mut self, seq_len: u32) {
        self.batch += 1;
        self.kv_tokens += seq_len as u64;
    }

    /// A decode request finished / its KV was freed.
    pub fn on_decode_leave(&mut self, seq_len: u32) {
        self.batch = self.batch.saturating_sub(1);
        self.kv_tokens = self.kv_tokens.saturating_sub(seq_len as u64);
    }

    /// Each decode step grows every resident sequence by one token.
    pub fn on_decode_step(&mut self) {
        self.kv_tokens += self.batch as u64;
    }
}

/// Readiness of one inference instance (the dispatch target of the
/// staggered loop; all its DP units receive a batch together because of
/// the DP sync barrier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstancePhase {
    /// No forward pass in flight; can accept a batch immediately.
    Ready,
    /// Executing a forward pass (non-preemptive, "locked" per §3.2).
    Busy,
    /// Watchdog-expired or health-check failed; excluded from dispatch.
    Suspect,
}

/// Per-instance view: phase plus device-queue depth.
#[derive(Debug, Clone)]
pub struct InstanceState {
    /// Pool-local instance index.
    pub index: u32,
    /// Current phase.
    pub phase: InstancePhase,
    /// Batches sitting in the device-side input queue (observable only
    /// through engine feedback; immediate dispatch drives this up).
    pub queue_depth: u32,
    /// Timestamp of the last dispatch to this instance.
    pub last_dispatch: f64,
    /// Timestamp of the last EndForward received from it.
    pub last_end_forward: f64,
}

impl InstanceState {
    /// Fresh ready instance.
    pub fn new(index: u32) -> Self {
        InstanceState {
            index,
            phase: InstancePhase::Ready,
            queue_depth: 0,
            last_dispatch: -1.0,
            last_end_forward: -1.0,
        }
    }
}

/// The full state plane for one pool (prefill or decode): instances plus
/// their DP units, indexable both ways.
#[derive(Debug, Clone)]
pub struct GlobalState {
    /// Instance-level states, length = pool size.
    pub instances: Vec<InstanceState>,
    /// Flattened DP-unit states, length = pool size × dp_per_instance.
    pub dps: Vec<DpState>,
    /// DP units per instance.
    pub dp_per_instance: u32,
}

impl GlobalState {
    /// Build a pool of `n_instances`, each with `dp_per_instance` units of
    /// chunk capacity `c_chunk`.
    pub fn new(n_instances: u32, dp_per_instance: u32, c_chunk: u32) -> Self {
        let instances = (0..n_instances).map(InstanceState::new).collect();
        let mut dps = Vec::with_capacity((n_instances * dp_per_instance) as usize);
        for i in 0..n_instances {
            for d in 0..dp_per_instance {
                dps.push(DpState::new(DpUnitId::new(i, d), c_chunk));
            }
        }
        GlobalState {
            instances,
            dps,
            dp_per_instance,
        }
    }

    /// Number of instances.
    pub fn n_instances(&self) -> u32 {
        self.instances.len() as u32
    }

    /// Flat index of a DP unit.
    pub fn dp_index(&self, id: DpUnitId) -> usize {
        (id.instance * self.dp_per_instance + id.dp) as usize
    }

    /// DP unit state by id.
    pub fn dp(&self, id: DpUnitId) -> &DpState {
        &self.dps[self.dp_index(id)]
    }

    /// Mutable DP unit state by id.
    pub fn dp_mut(&mut self, id: DpUnitId) -> &mut DpState {
        let i = self.dp_index(id);
        &mut self.dps[i]
    }

    /// The DP-unit slice belonging to one instance.
    pub fn instance_dps(&self, instance: u32) -> &[DpState] {
        let a = (instance * self.dp_per_instance) as usize;
        let b = a + self.dp_per_instance as usize;
        &self.dps[a..b]
    }

    /// Mutable DP-unit slice of one instance.
    pub fn instance_dps_mut(&mut self, instance: u32) -> &mut [DpState] {
        let a = (instance * self.dp_per_instance) as usize;
        let b = a + self.dp_per_instance as usize;
        &mut self.dps[a..b]
    }

    /// Instances currently in the given phase.
    pub fn instances_in(&self, phase: InstancePhase) -> impl Iterator<Item = &InstanceState> {
        self.instances.iter().filter(move |i| i.phase == phase)
    }

    /// Count of non-suspect instances (the `N_active` of Algorithm 1).
    pub fn n_active(&self) -> u32 {
        self.instances
            .iter()
            .filter(|i| i.phase != InstancePhase::Suspect)
            .count() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c_avail_matches_formula() {
        let mut d = DpState::new(DpUnitId::new(0, 0), 3072);
        assert_eq!(d.c_avail(), 3072);
        d.on_dispatch(1000);
        assert_eq!(d.c_avail(), 2072);
        d.on_ack(1000);
        assert_eq!(d.c_avail(), 2072); // flight→queued, headroom unchanged
        assert_eq!(d.u_flight, 0);
        assert_eq!(d.r_queued, 1000);
        d.on_consumed(600);
        assert_eq!(d.c_avail(), 2672);
    }

    #[test]
    fn c_avail_can_go_negative() {
        let mut d = DpState::new(DpUnitId::new(0, 0), 100);
        d.on_dispatch(250); // long request spanning multiple chunks
        assert_eq!(d.c_avail(), -150);
    }

    #[test]
    fn decode_state_updates() {
        let mut d = DpState::new(DpUnitId::new(0, 1), 0);
        d.on_decode_join(2500);
        d.on_decode_join(100);
        assert_eq!(d.batch, 2);
        assert_eq!(d.kv_tokens, 2600);
        d.on_decode_step();
        assert_eq!(d.kv_tokens, 2602);
        d.on_decode_leave(2501);
        assert_eq!(d.batch, 1);
        assert_eq!(d.kv_tokens, 101);
    }

    #[test]
    fn pool_indexing() {
        let g = GlobalState::new(3, 8, 3072);
        assert_eq!(g.dps.len(), 24);
        assert_eq!(g.dp(DpUnitId::new(2, 5)).id, DpUnitId::new(2, 5));
        assert_eq!(g.instance_dps(1).len(), 8);
        assert_eq!(g.instance_dps(1)[0].id.instance, 1);
        assert_eq!(g.n_active(), 3);
    }

    #[test]
    fn n_active_excludes_suspect() {
        let mut g = GlobalState::new(4, 1, 1024);
        g.instances[2].phase = InstancePhase::Suspect;
        assert_eq!(g.n_active(), 3);
        assert_eq!(g.instances_in(InstancePhase::Ready).count(), 3);
    }
}
