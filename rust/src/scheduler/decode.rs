//! Algorithm 3 — IQR-Aware Lexicographical Decode Scheduling.
//!
//! Decode suffers a *coupled* imbalance: KV-cache memory (heavy-tailed
//! sequence lengths) and batch size (GPU utilization) must be balanced
//! together. Per request, the scheduler:
//!
//! 1. **Masks outliers**: units with `K_n > Q3 + k·IQR` of the current KV
//!    snapshot are excluded (robust to heavy tails where mean/variance
//!    thresholds misfire); if all are masked, fall back to all units.
//! 2. **Selects lexicographically**: minimal `⟨B_i, K_i⟩` — batch size
//!    first (parallel efficiency), KV load as tie-breaker (memory
//!    pressure).
//! 3. **Updates state**: `B ← B+1`, `K ← K + Length(r)`.
//!
//! Requests are pre-sorted by total length descending ("fill-the-valley"):
//! heavy requests place while the decision space is widest.

use super::state::DpState;
use super::types::{DpUnitId, Request};
use crate::util::stats::Iqr;

/// Algorithm 3 configuration.
#[derive(Debug, Clone)]
pub struct DecodeSchedConfig {
    /// IQR multiplier threshold `k` (paper: typically 1.5).
    pub iqr_k: f64,
    /// Enable the outlier mask (disable for the ablation).
    pub mask_outliers: bool,
    /// Enable length pre-sorting (disable for the ablation).
    pub pre_sort: bool,
}

impl Default for DecodeSchedConfig {
    fn default() -> Self {
        DecodeSchedConfig {
            iqr_k: 1.5,
            mask_outliers: true,
            pre_sort: true,
        }
    }
}

/// One decode placement.
#[derive(Debug, Clone)]
pub struct DecodeAssignment {
    /// The placed request.
    pub request: Request,
    /// Receiving DP unit.
    pub unit: DpUnitId,
}

/// `LexCompare(i, j)`: `(B_i < B_j) or (B_i == B_j and K_i < K_j)`.
#[inline]
pub fn lex_less(a: &DpState, b: &DpState) -> bool {
    a.batch < b.batch || (a.batch == b.batch && a.kv_tokens < b.kv_tokens)
}

/// Schedule a batch of decode requests onto `dps` (state updated in
/// place). Returns the assignment list in placement order.
pub fn schedule_batch(
    cfg: &DecodeSchedConfig,
    mut batch: Vec<Request>,
    dps: &mut [DpState],
) -> Vec<DecodeAssignment> {
    assert!(!dps.is_empty(), "decode pool is empty");
    if cfg.pre_sort {
        // Descending total sequence length; stable to preserve FCFS among
        // equals.
        batch.sort_by(|a, b| b.total_len().cmp(&a.total_len()));
    }

    let mut out = Vec::with_capacity(batch.len());
    // Perf: the IQR needs the *sorted* KV snapshot every iteration; a
    // full re-sort per request is O(R·D log D). Maintain the sorted
    // vector incrementally instead (remove-old + insert-new per
    // placement): O(R·D) worst case, ~O(R·log D) typical.
    let mut sorted_kv: Vec<f64> = dps.iter().map(|d| d.kv_tokens as f64).collect();
    sorted_kv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for r in batch {
        // Step 1: outlier detection on the *current* KV snapshot.
        let threshold = if cfg.mask_outliers {
            let q1 = crate::util::stats::percentile_sorted(&sorted_kv, 25.0);
            let q3 = crate::util::stats::percentile_sorted(&sorted_kv, 75.0);
            Some(Iqr { q1, q3 }.outlier_threshold(cfg.iqr_k))
        } else {
            None
        };

        // Step 2: lexicographic selection within the safe set; fallback to
        // all units when the mask empties the pool.
        let mut best: Option<usize> = None;
        if let Some(th) = threshold {
            for (i, d) in dps.iter().enumerate() {
                if d.kv_tokens as f64 > th {
                    continue;
                }
                if best.map_or(true, |b| lex_less(d, &dps[b])) {
                    best = Some(i);
                }
            }
        }
        if best.is_none() {
            for (i, d) in dps.iter().enumerate() {
                if best.map_or(true, |b| lex_less(d, &dps[b])) {
                    best = Some(i);
                }
            }
        }
        let i = best.expect("non-empty pool");

        // Step 3: assignment and state update (+ incremental snapshot
        // maintenance: replace the chosen unit's old KV value).
        let old_kv = dps[i].kv_tokens as f64;
        dps[i].on_decode_join(r.total_len());
        if cfg.mask_outliers {
            let pos = sorted_kv
                .binary_search_by(|x| x.partial_cmp(&old_kv).unwrap())
                .unwrap_or_else(|p| p.min(sorted_kv.len() - 1));
            sorted_kv.remove(pos);
            let new_kv = dps[i].kv_tokens as f64;
            let ins = sorted_kv
                .binary_search_by(|x| x.partial_cmp(&new_kv).unwrap())
                .unwrap_or_else(|p| p);
            sorted_kv.insert(ins, new_kv);
        }
        out.push(DecodeAssignment {
            unit: dps[i].id,
            request: r,
        });
    }
    out
}

/// Baseline decode placement used in the Fig. 7/8 comparison: immediate
/// hash/random routing, blind to KV/batch state (what session-affinity
/// routers degenerate to across DP units). Deterministic given the
/// caller-held rng.
pub fn schedule_random(
    batch: Vec<Request>,
    dps: &mut [DpState],
    rng: &mut crate::util::Rng,
) -> Vec<DecodeAssignment> {
    assert!(!dps.is_empty());
    let mut out = Vec::with_capacity(batch.len());
    for r in batch {
        let i = rng.index(dps.len());
        dps[i].on_decode_join(r.total_len());
        out.push(DecodeAssignment {
            unit: dps[i].id,
            request: r,
        });
    }
    out
}

/// Ablation baseline: strict round-robin (equal counts, blind to KV).
pub fn schedule_round_robin(
    batch: Vec<Request>,
    dps: &mut [DpState],
    cursor: &mut usize,
) -> Vec<DecodeAssignment> {
    assert!(!dps.is_empty());
    let mut out = Vec::with_capacity(batch.len());
    for r in batch {
        let i = *cursor % dps.len();
        *cursor = cursor.wrapping_add(1);
        dps[i].on_decode_join(r.total_len());
        out.push(DecodeAssignment {
            unit: dps[i].id,
            request: r,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n: usize) -> Vec<DpState> {
        (0..n)
            .map(|i| DpState::new(DpUnitId::new(0, i as u32), 0))
            .collect()
    }

    fn req(id: u64, total: u32) -> Request {
        Request::new(id, total, 0, 0.0)
    }

    #[test]
    fn lex_prefers_smaller_batch_then_kv() {
        let mut a = DpState::new(DpUnitId::new(0, 0), 0);
        let mut b = DpState::new(DpUnitId::new(0, 1), 0);
        a.batch = 1;
        a.kv_tokens = 10;
        b.batch = 2;
        b.kv_tokens = 1;
        assert!(lex_less(&a, &b)); // batch dominates
        b.batch = 1;
        assert!(lex_less(&b, &a)); // kv breaks the tie
    }

    #[test]
    fn balances_batch_sizes() {
        let mut dps = pool(4);
        let batch: Vec<Request> = (0..8).map(|i| req(i, 100)).collect();
        schedule_batch(&DecodeSchedConfig::default(), batch, &mut dps);
        for d in &dps {
            assert_eq!(d.batch, 2);
        }
    }

    #[test]
    fn heavy_requests_spread_by_kv_tiebreak() {
        let mut dps = pool(2);
        // Equal batch counts force the KV tie-break to alternate heavy/light.
        let batch = vec![req(0, 1000), req(1, 1000), req(2, 10), req(3, 10)];
        schedule_batch(&DecodeSchedConfig::default(), batch, &mut dps);
        assert_eq!(dps[0].kv_tokens, 1010);
        assert_eq!(dps[1].kv_tokens, 1010);
    }

    #[test]
    fn outlier_unit_is_masked() {
        let mut dps = pool(4);
        dps[3].kv_tokens = 1_000_000; // saturated straggler
        dps[3].batch = 0; // would win lexicographically without the mask
        for d in dps.iter_mut().take(3) {
            d.batch = 5;
            d.kv_tokens = 1000;
        }
        let out = schedule_batch(&DecodeSchedConfig::default(), vec![req(0, 100)], &mut dps);
        assert_ne!(out[0].unit, DpUnitId::new(0, 3), "straggler must be masked");
    }

    #[test]
    fn mask_disabled_places_on_straggler() {
        let cfg = DecodeSchedConfig {
            mask_outliers: false,
            ..Default::default()
        };
        let mut dps = pool(4);
        dps[3].kv_tokens = 1_000_000;
        for d in dps.iter_mut().take(3) {
            d.batch = 5;
        }
        let out = schedule_batch(&cfg, vec![req(0, 100)], &mut dps);
        assert_eq!(out[0].unit, DpUnitId::new(0, 3)); // B=0 wins unmasked
    }

    #[test]
    fn all_masked_falls_back_to_all() {
        let mut dps = pool(2);
        dps[0].kv_tokens = 100;
        dps[1].kv_tokens = 100;
        // Uniform loads: IQR = 0, threshold = 100; nothing above it, so
        // nothing is masked. Force the degenerate all-masked case with a
        // negative-k configuration.
        let cfg = DecodeSchedConfig {
            iqr_k: -10.0,
            ..Default::default()
        };
        let out = schedule_batch(&cfg, vec![req(0, 50)], &mut dps);
        assert_eq!(out.len(), 1); // fallback path still places
    }

    #[test]
    fn presort_places_heavy_first() {
        let mut dps = pool(2);
        let batch = vec![req(0, 10), req(1, 5000)];
        let out = schedule_batch(&DecodeSchedConfig::default(), batch, &mut dps);
        assert_eq!(out[0].request.id, 1, "heaviest first (fill-the-valley)");
    }

    #[test]
    fn random_placement_is_blind_and_deterministic() {
        let mut dps = pool(4);
        dps[0].kv_tokens = 1_000_000;
        let mut rng = crate::util::Rng::new(9);
        let batch: Vec<Request> = (0..64).map(|i| req(i, 10)).collect();
        let a = schedule_random(batch.clone(), &mut dps, &mut rng);
        // Blind: the saturated unit still receives work.
        assert!(a.iter().any(|x| x.unit.dp == 0));
        // Deterministic given the seed.
        let mut dps2 = pool(4);
        dps2[0].kv_tokens = 1_000_000;
        let mut rng2 = crate::util::Rng::new(9);
        let b = schedule_random(batch, &mut dps2, &mut rng2);
        assert_eq!(
            a.iter().map(|x| x.unit).collect::<Vec<_>>(),
            b.iter().map(|x| x.unit).collect::<Vec<_>>()
        );
    }

    #[test]
    fn round_robin_ignores_state() {
        let mut dps = pool(2);
        dps[0].kv_tokens = 1_000_000;
        let mut cursor = 0;
        let out = schedule_round_robin(vec![req(0, 10), req(1, 10)], &mut dps, &mut cursor);
        assert_eq!(out[0].unit, DpUnitId::new(0, 0)); // blind
        assert_eq!(out[1].unit, DpUnitId::new(0, 1));
    }

    #[test]
    fn snapshot_updates_between_placements() {
        // After enough placements on the low units, the straggler's mask
        // should eventually lift as Q3 rises.
        let mut dps = pool(3);
        dps[2].kv_tokens = 10_000;
        let batch: Vec<Request> = (0..40).map(|i| req(i, 1000)).collect();
        schedule_batch(&DecodeSchedConfig::default(), batch, &mut dps);
        assert!(
            dps[2].batch > 0,
            "straggler re-enters once others catch up: {:?}",
            dps.iter().map(|d| (d.batch, d.kv_tokens)).collect::<Vec<_>>()
        );
    }
}
