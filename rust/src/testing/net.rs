//! TCP test harness for the serving frontend: spawn a mock-engine server
//! on an ephemeral port and drive it with line-protocol clients. Used by
//! the `server_concurrency` integration suite; kept in the library so
//! examples and future stress drivers can reuse it.

use crate::cluster::workers::RealClusterConfig;
use crate::server;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A serving frontend running on its own thread, bound to an ephemeral
/// port. Call [`TestServer::shutdown`] to drain and join it.
pub struct TestServer {
    /// Bound address (`127.0.0.1:<port>`).
    pub addr: String,
    thread: Option<JoinHandle<Result<()>>>,
}

impl TestServer {
    /// Bind `127.0.0.1:0` and run [`server::serve_listener`] with `cfg`.
    pub fn start(cfg: RealClusterConfig) -> TestServer {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
        let addr = listener.local_addr().expect("local addr").to_string();
        let thread = std::thread::spawn(move || server::serve_listener(cfg, listener));
        TestServer {
            addr,
            thread: Some(thread),
        }
    }

    /// Send `SHUTDOWN`, wait for the server to drain in-flight jobs and
    /// exit, and surface any server-side error.
    pub fn shutdown(mut self) -> Result<()> {
        crate::workload::loadgen::send_shutdown(&self.addr)?;
        match self.thread.take().expect("not yet joined").join() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("server thread panicked")),
        }
    }
}

/// Poll-connect `addr` until something accepts or `timeout` elapses —
/// the handshake-free way to wait for a just-spawned server or shard
/// process to finish binding.
pub fn wait_for_port(addr: &str, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(_) => return Ok(()),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!("nothing listening on {addr} after {timeout:?}: {e}"))
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

/// Parse the `LISTENING <addr>` announcement an `sbs worker` process
/// prints after binding (how a parent learns an ephemeral port).
pub fn parse_listening_line(line: &str) -> Result<String> {
    line.trim()
        .strip_prefix("LISTENING ")
        .map(str::to_string)
        .ok_or_else(|| anyhow!("expected 'LISTENING <addr>', got {line:?}"))
}

/// One parsed server reply line.
#[derive(Debug, Clone)]
pub enum Reply {
    /// `TOK <id> <index> <token>`
    Tok {
        /// Job id.
        id: u64,
        /// 0-based token index.
        index: u32,
        /// Token id.
        token: i32,
    },
    /// `DONE <id> ...` (full line kept for assertions).
    Done {
        /// Job id.
        id: u64,
        /// The raw line.
        line: String,
    },
    /// `STATS <json>` (decode-pool gauges snapshot).
    Stats {
        /// The raw JSON payload.
        json: String,
    },
    /// `BUSY <reason>`
    Busy {
        /// `queue_full`, `throttled` or `rejected`.
        reason: String,
    },
    /// `ERR <message>`
    Err(String),
    /// `BYE` (shutdown acknowledgement).
    Bye,
}

/// Everything observed while streaming one `GEN`.
#[derive(Debug, Default)]
pub struct GenOutcome {
    /// The request was shed (`BUSY`).
    pub busy: bool,
    /// Streamed token ids in arrival order.
    pub tokens: Vec<i32>,
    /// Receive instant of each token (interleaving assertions).
    pub tok_times: Vec<Instant>,
    /// Raw `DONE` line, when the generation completed.
    pub done: Option<String>,
}

/// Parse one server reply line — the single decoder for the wire
/// protocol, shared by [`LineClient`] and the load generator so the two
/// cannot drift apart.
pub fn parse_reply(l: &str) -> Reply {
    // Keep the raw JSON payload intact (it contains spaces).
    if let Some(json) = l.strip_prefix("STATS ") {
        return Reply::Stats {
            json: json.to_string(),
        };
    }
    let mut parts = l.split_whitespace();
    match parts.next() {
        Some("TOK") => {
            let id = parts.next().and_then(|x| x.parse().ok()).unwrap_or(0);
            let index = parts.next().and_then(|x| x.parse().ok()).unwrap_or(0);
            let token = parts.next().and_then(|x| x.parse().ok()).unwrap_or(0);
            Reply::Tok { id, index, token }
        }
        Some("DONE") => Reply::Done {
            id: parts.next().and_then(|x| x.parse().ok()).unwrap_or(0),
            line: l.to_string(),
        },
        Some("BUSY") => Reply::Busy {
            reason: parts.next().unwrap_or("").to_string(),
        },
        Some("BYE") => Reply::Bye,
        _ => Reply::Err(l.to_string()),
    }
}

/// Blocking line-protocol client with a 30 s read timeout (so a wedged
/// server fails tests instead of hanging them).
pub struct LineClient {
    reader: BufReader<TcpStream>,
    out: TcpStream,
}

impl LineClient {
    /// Connect to a [`TestServer`] address.
    pub fn connect(addr: &str) -> Result<LineClient> {
        let conn = TcpStream::connect(addr)?;
        conn.set_read_timeout(Some(Duration::from_secs(30)))?;
        conn.set_nodelay(true)?;
        Ok(LineClient {
            reader: BufReader::new(conn.try_clone()?),
            out: conn,
        })
    }

    /// Send one protocol line.
    pub fn send(&mut self, line: &str) -> Result<()> {
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    /// Read one reply; `None` on clean EOF.
    pub fn recv(&mut self) -> Result<Option<Reply>> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        Ok(Some(parse_reply(line.trim())))
    }

    /// Send one `GEN` and stream it to its terminal reply.
    pub fn gen(&mut self, max_new: u32, prompt: &str) -> Result<GenOutcome> {
        self.send(&format!("GEN {max_new} {prompt}"))?;
        let mut out = GenOutcome::default();
        loop {
            match self.recv()? {
                Some(Reply::Tok { token, .. }) => {
                    out.tokens.push(token);
                    out.tok_times.push(Instant::now());
                }
                Some(Reply::Done { line, .. }) => {
                    out.done = Some(line);
                    return Ok(out);
                }
                // A STATS reply can only be a response to a STATS request,
                // never part of a GEN stream; tolerate and keep reading.
                Some(Reply::Stats { .. }) => {}
                Some(Reply::Busy { .. }) => {
                    out.busy = true;
                    return Ok(out);
                }
                Some(Reply::Err(e)) => return Err(anyhow!("server error: {e}")),
                Some(Reply::Bye) | None => return Err(anyhow!("connection closed mid-GEN")),
            }
        }
    }
}
