//! TCP test harnesses: spawn a mock-engine serving frontend on an
//! ephemeral port and drive it with line-protocol clients
//! ([`TestServer`] / [`LineClient`], used by the `server_concurrency`
//! suite), and impersonate a shard on the binary transport protocol
//! ([`FakeShard`] / [`ShardConn`], used by the `transport_faults` suite
//! to inject truncated/corrupt/reordered streams and abrupt deaths
//! deterministically). Kept in the library so examples and future
//! stress drivers can reuse them.

use crate::cluster::workers::RealClusterConfig;
use crate::server;
use crate::transport::proto::{self, Frame, FrameReader, ShardRole, StreamId, PROTO_VERSION};
use crate::transport::KvCodec;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A serving frontend running on its own thread, bound to an ephemeral
/// port. Call [`TestServer::shutdown`] to drain and join it.
pub struct TestServer {
    /// Bound address (`127.0.0.1:<port>`).
    pub addr: String,
    thread: Option<JoinHandle<Result<()>>>,
}

impl TestServer {
    /// Bind `127.0.0.1:0` and run [`server::serve_listener`] with `cfg`.
    pub fn start(cfg: RealClusterConfig) -> TestServer {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
        let addr = listener.local_addr().expect("local addr").to_string();
        let thread = std::thread::spawn(move || server::serve_listener(cfg, listener));
        TestServer {
            addr,
            thread: Some(thread),
        }
    }

    /// Send `SHUTDOWN`, wait for the server to drain in-flight jobs and
    /// exit, and surface any server-side error.
    pub fn shutdown(mut self) -> Result<()> {
        crate::workload::loadgen::send_shutdown(&self.addr)?;
        match self.thread.take().expect("not yet joined").join() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("server thread panicked")),
        }
    }
}

/// Poll-connect `addr` until something accepts or `timeout` elapses —
/// the handshake-free way to wait for a just-spawned server or shard
/// process to finish binding.
pub fn wait_for_port(addr: &str, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(_) => return Ok(()),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!("nothing listening on {addr} after {timeout:?}: {e}"))
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

/// One accepted scheduler connection on a [`FakeShard`], as seen from
/// the shard side: send frames (or raw bytes — malformed on purpose),
/// receive the scheduler's frames with a deadline, or kill the
/// connection abruptly. Everything is driven by the test's script
/// closure, so fault sequences are fully deterministic.
pub struct ShardConn {
    conn: TcpStream,
    reader: FrameReader,
}

impl ShardConn {
    /// Send one well-formed frame.
    pub fn send(&mut self, f: &Frame) -> Result<()> {
        proto::write_frame(&mut self.conn, f)?;
        Ok(())
    }

    /// Send raw bytes verbatim — the fault-injection path (truncated
    /// frames, corrupt length prefixes, garbage tags).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.conn.write_all(bytes)?;
        Ok(())
    }

    /// Receive the next frame within `timeout`.
    pub fn recv(&mut self, timeout: Duration) -> Result<Frame> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.reader.poll(&mut self.conn) {
                Ok(Some(f)) => return Ok(f),
                Ok(None) if Instant::now() < deadline => continue,
                Ok(None) => return Err(anyhow!("no frame within {timeout:?}")),
                Err(e) => return Err(anyhow!("receive failed: {e}")),
            }
        }
    }

    /// Receive frames until `pred` matches one (bounded by `timeout`).
    pub fn recv_until(
        &mut self,
        timeout: Duration,
        mut pred: impl FnMut(&Frame) -> bool,
    ) -> Result<Frame> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| anyhow!("no matching frame within {timeout:?}"))?;
            let f = self.recv(left)?;
            if pred(&f) {
                return Ok(f);
            }
        }
    }

    /// Receive the next frame within `timeout`, tagged with the
    /// [`StreamId`] from its header — for multiplexing assertions.
    pub fn recv_stream(&mut self, timeout: Duration) -> Result<(StreamId, Frame)> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.reader.poll_stream(&mut self.conn) {
                Ok(Some(tagged)) => return Ok(tagged),
                Ok(None) if Instant::now() < deadline => continue,
                Ok(None) => return Err(anyhow!("no frame within {timeout:?}")),
                Err(e) => return Err(anyhow!("receive failed: {e}")),
            }
        }
    }

    /// Capture stream-tagged frames in arrival order until `done` says
    /// the capture is complete (called after each frame with the whole
    /// capture so far), bounded by `timeout`. This is how interleaving
    /// tests prove two logical streams actually alternated on one
    /// socket: the returned sequence preserves wire order.
    pub fn capture_streams(
        &mut self,
        timeout: Duration,
        mut done: impl FnMut(&[(StreamId, Frame)]) -> bool,
    ) -> Result<Vec<(StreamId, Frame)>> {
        let deadline = Instant::now() + timeout;
        let mut captured = Vec::new();
        loop {
            let left = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| {
                    anyhow!("capture incomplete after {timeout:?} ({} frames)", captured.len())
                })?;
            captured.push(self.recv_stream(left)?);
            if done(&captured) {
                return Ok(captured);
            }
        }
    }

    /// Kill the connection abruptly (RST-ish: both halves shut down) —
    /// the mid-handoff peer-death injection.
    pub fn kill(self) {
        let _ = self.conn.shutdown(Shutdown::Both);
    }
}

/// Accept one direct-transfer peer connection on `listener` and serve
/// the `PeerHello`/`PeerHelloAck` handshake, returning the live
/// connection and the codec the dialer proposed. The test plays the
/// decode-shard side: capture `KvSegment`/`HandoffCommit` frames (with
/// [`ShardConn::capture_streams`]) and ack — or withhold acks / kill
/// the connection — to script multiplexed-handoff faults.
pub fn accept_peer(listener: &TcpListener, timeout: Duration) -> Result<(ShardConn, KvCodec)> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + timeout;
    let conn = loop {
        match listener.accept() {
            Ok((conn, _)) => break conn,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("no peer connection within {timeout:?}"));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(anyhow!("peer accept failed: {e}")),
        }
    };
    conn.set_nonblocking(false)?;
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(Duration::from_millis(50)))?;
    let mut sc = ShardConn {
        conn,
        reader: FrameReader::new(),
    };
    match sc.recv(Duration::from_secs(5))? {
        Frame::PeerHello { version, kv_wire } if version == PROTO_VERSION => {
            sc.send(&Frame::PeerHelloAck {
                version: PROTO_VERSION,
            })?;
            Ok((sc, kv_wire))
        }
        other => Err(anyhow!("expected PeerHello, got {other:?}")),
    }
}

/// A scripted fake shard: binds an ephemeral port, serves the
/// `Hello`/`HelloAck` handshake with *whatever ack the test supplies*
/// (wrong versions, roles and codecs included), then hands the live
/// connection to the test's script closure. One connection per accept;
/// the accept loop keeps serving so scheduler-side reconnects find it
/// again (each reconnect re-runs `on_accept` to build a fresh script).
pub struct FakeShard {
    /// Bound address (`127.0.0.1:<port>`).
    pub addr: String,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl FakeShard {
    /// Standard well-formed ack for `role` (shape 1×4, echoing `codec`).
    pub fn ack(role: ShardRole, codec: KvCodec) -> Frame {
        Frame::HelloAck {
            version: PROTO_VERSION,
            role,
            units: 1,
            slots: 4,
            kv_wire: codec,
            peer_port: 0,
        }
    }

    /// Spawn a fake shard answering every handshake with `ack` and then
    /// running `script` on the connection. The scheduler's `Hello` is
    /// consumed (its proposed codec passed to the script); a script
    /// returning (or erroring) drops that connection and the shard goes
    /// back to accepting.
    pub fn serve<F>(ack: Frame, script: F) -> FakeShard
    where
        F: Fn(ShardConn, KvCodec) -> Result<()> + Send + 'static,
    {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
        let addr = listener.local_addr().expect("local addr").to_string();
        listener.set_nonblocking(true).expect("nonblocking accept");
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = stop.clone();
        let thread = std::thread::spawn(move || {
            use std::sync::atomic::Ordering;
            loop {
                match listener.accept() {
                    Ok((conn, _)) => {
                        conn.set_nonblocking(false).expect("blocking conn");
                        conn.set_nodelay(true).expect("nodelay");
                        conn.set_read_timeout(Some(Duration::from_millis(50)))
                            .expect("read timeout");
                        let mut sc = ShardConn {
                            conn,
                            reader: FrameReader::new(),
                        };
                        let proposed = match sc.recv(Duration::from_secs(5)) {
                            Ok(Frame::Hello { kv_wire, .. }) => kv_wire,
                            _ => continue, // not a handshake; drop
                        };
                        if sc.send(&ack).is_err() {
                            continue;
                        }
                        if let Err(e) = script(sc, proposed) {
                            log::debug!("fake shard script ended: {e:#}");
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if flag.load(Ordering::SeqCst) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            }
        });
        FakeShard {
            addr,
            stop,
            thread: Some(thread),
        }
    }
}

impl Drop for FakeShard {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Parse the `LISTENING <addr>` announcement an `sbs worker` process
/// prints after binding (how a parent learns an ephemeral port).
pub fn parse_listening_line(line: &str) -> Result<String> {
    line.trim()
        .strip_prefix("LISTENING ")
        .map(str::to_string)
        .ok_or_else(|| anyhow!("expected 'LISTENING <addr>', got {line:?}"))
}

/// One parsed server reply line.
#[derive(Debug, Clone)]
pub enum Reply {
    /// `TOK <id> <index> <token>`
    Tok {
        /// Job id.
        id: u64,
        /// 0-based token index.
        index: u32,
        /// Token id.
        token: i32,
    },
    /// `DONE <id> ...` (full line kept for assertions).
    Done {
        /// Job id.
        id: u64,
        /// The raw line.
        line: String,
    },
    /// `STATS <json>` (decode-pool gauges snapshot).
    Stats {
        /// The raw JSON payload.
        json: String,
    },
    /// `BUSY <reason>`
    Busy {
        /// `queue_full`, `throttled` or `rejected`.
        reason: String,
    },
    /// `ERR <message>`
    Err(String),
    /// `BYE` (shutdown acknowledgement).
    Bye,
}

/// Everything observed while streaming one `GEN`.
#[derive(Debug, Default)]
pub struct GenOutcome {
    /// The request was shed (`BUSY`).
    pub busy: bool,
    /// Streamed token ids in arrival order.
    pub tokens: Vec<i32>,
    /// Receive instant of each token (interleaving assertions).
    pub tok_times: Vec<Instant>,
    /// Raw `DONE` line, when the generation completed.
    pub done: Option<String>,
}

/// Parse one server reply line — the single decoder for the wire
/// protocol, shared by [`LineClient`] and the load generator so the two
/// cannot drift apart.
pub fn parse_reply(l: &str) -> Reply {
    // Keep the raw JSON payload intact (it contains spaces).
    if let Some(json) = l.strip_prefix("STATS ") {
        return Reply::Stats {
            json: json.to_string(),
        };
    }
    let mut parts = l.split_whitespace();
    match parts.next() {
        Some("TOK") => {
            let id = parts.next().and_then(|x| x.parse().ok()).unwrap_or(0);
            let index = parts.next().and_then(|x| x.parse().ok()).unwrap_or(0);
            let token = parts.next().and_then(|x| x.parse().ok()).unwrap_or(0);
            Reply::Tok { id, index, token }
        }
        Some("DONE") => Reply::Done {
            id: parts.next().and_then(|x| x.parse().ok()).unwrap_or(0),
            line: l.to_string(),
        },
        Some("BUSY") => Reply::Busy {
            reason: parts.next().unwrap_or("").to_string(),
        },
        Some("BYE") => Reply::Bye,
        _ => Reply::Err(l.to_string()),
    }
}

/// Blocking line-protocol client with a 30 s read timeout (so a wedged
/// server fails tests instead of hanging them).
pub struct LineClient {
    reader: BufReader<TcpStream>,
    out: TcpStream,
}

impl LineClient {
    /// Connect to a [`TestServer`] address.
    pub fn connect(addr: &str) -> Result<LineClient> {
        let conn = TcpStream::connect(addr)?;
        conn.set_read_timeout(Some(Duration::from_secs(30)))?;
        conn.set_nodelay(true)?;
        Ok(LineClient {
            reader: BufReader::new(conn.try_clone()?),
            out: conn,
        })
    }

    /// Send one protocol line.
    pub fn send(&mut self, line: &str) -> Result<()> {
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    /// Read one reply; `None` on clean EOF.
    pub fn recv(&mut self) -> Result<Option<Reply>> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        Ok(Some(parse_reply(line.trim())))
    }

    /// Send one `GEN` and stream it to its terminal reply.
    pub fn gen(&mut self, max_new: u32, prompt: &str) -> Result<GenOutcome> {
        self.send(&format!("GEN {max_new} {prompt}"))?;
        let mut out = GenOutcome::default();
        loop {
            match self.recv()? {
                Some(Reply::Tok { token, .. }) => {
                    out.tokens.push(token);
                    out.tok_times.push(Instant::now());
                }
                Some(Reply::Done { line, .. }) => {
                    out.done = Some(line);
                    return Ok(out);
                }
                // A STATS reply can only be a response to a STATS request,
                // never part of a GEN stream; tolerate and keep reading.
                Some(Reply::Stats { .. }) => {}
                Some(Reply::Busy { .. }) => {
                    out.busy = true;
                    return Ok(out);
                }
                Some(Reply::Err(e)) => return Err(anyhow!("server error: {e}")),
                Some(Reply::Bye) | None => return Err(anyhow!("connection closed mid-GEN")),
            }
        }
    }
}
