//! Seeded property-testing mini-framework (no `proptest` in the offline
//! registry).
//!
//! [`check`] runs a property over `cases` randomly-generated inputs. On
//! failure it retries with progressively "smaller" regenerated inputs
//! (size-bounded regeneration — a pragmatic stand-in for shrinking) and
//! panics with the failing seed so the case can be replayed exactly:
//!
//! ```no_run
//! use sbs::testing::{check, Gen};
//! check("sum is commutative", 200, |g| {
//!     let a = g.rng.f64();
//!     let b = g.rng.f64();
//!     assert!((a + b - (b + a)).abs() < 1e-15);
//! });
//! ```

pub mod net;
pub mod scenarios;

use crate::util::Rng;

/// Per-case generation context: an rng plus a size hint in `[0, 1]` that
/// grows over the run (small cases first, like proptest).
pub struct Gen {
    /// Deterministic source of randomness for this case.
    pub rng: Rng,
    /// Size hint in `[0, 1]`; multiply your max collection length by this.
    pub size: f64,
}

impl Gen {
    /// A length in `[1, max]` scaled by the current size hint.
    pub fn len(&mut self, max: usize) -> usize {
        let cap = ((max as f64 * self.size).ceil() as usize).max(1);
        1 + self.rng.index(cap)
    }

    /// A vector of `n` values drawn by `f`.
    pub fn vec_of<T>(&mut self, n: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        (0..n).map(|_| f(&mut self.rng)).collect()
    }
}

/// Environment knob: `SBS_PROPTEST_CASES` overrides the case count.
fn case_count(default_cases: u32) -> u32 {
    std::env::var("SBS_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

/// Run `prop` over `cases` generated inputs. Panics (with the seed) on the
/// first failing case after attempting smaller reproductions.
pub fn check(name: &str, cases: u32, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let cases = case_count(cases);
    let base_seed = BASE_SEED ^ hash_name(name);
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64);
        let size = (i as f64 + 1.0) / cases as f64;
        if let Err(panic) = run_case(&prop, seed, size) {
            // Try smaller sizes with the same seed to report a more
            // minimal configuration.
            let mut min_size = size;
            let mut steps = 0;
            let mut s = size / 2.0;
            while steps < 16 && s > 1e-3 {
                if run_case(&prop, seed, s).is_err() {
                    min_size = s;
                    s /= 2.0;
                } else {
                    s = (s + min_size) / 2.0;
                }
                steps += 1;
            }
            let msg = panic_text(&panic);
            panic!(
                "property '{name}' failed (case {i}, seed {seed:#x}, size {min_size:.4}): {msg}\n\
                 replay: sbs::testing::replay(\"{name}\", {seed:#x}, {min_size:.6}, prop)"
            );
        }
    }
}

/// Replay a single case by seed/size (used to debug failures reported by
/// [`check`]).
pub fn replay(name: &str, seed: u64, size: f64, prop: impl Fn(&mut Gen)) {
    let _ = name;
    let mut g = Gen {
        rng: Rng::new(seed),
        size,
    };
    prop(&mut g);
}

fn run_case(
    prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
    seed: u64,
    size: f64,
) -> Result<(), Box<dyn std::any::Any + Send>> {
    std::panic::catch_unwind(|| {
        let mut g = Gen {
            rng: Rng::new(seed),
            size,
        };
        prop(&mut g);
    })
}

fn panic_text(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Base seed for all properties; change to re-roll the whole suite.
const BASE_SEED: u64 = 0x5B5_0000_5EED;

fn hash_name(name: &str) -> u64 {
    // FNV-1a, good enough to decorrelate property seeds.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 64, |g| {
            let n = g.len(32);
            let mut v = g.vec_of(n, |r| r.next_u64());
            let orig = v.clone();
            v.reverse();
            v.reverse();
            assert_eq!(v, orig);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_reports_seed() {
        check("always fails", 8, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn deterministic_cases() {
        use std::sync::Mutex;
        let seen = Mutex::new(Vec::new());
        check("collect", 4, |g| {
            seen.lock().unwrap().push(g.rng.next_u64());
        });
        let first = seen.lock().unwrap().clone();
        seen.lock().unwrap().clear();
        check("collect", 4, |g| {
            seen.lock().unwrap().push(g.rng.next_u64());
        });
        assert_eq!(first, *seen.lock().unwrap());
    }
}
