//! Reusable live-cluster scenarios shared by the integration suite and
//! the benches, so both always measure the same configuration.

use crate::cluster::dispatch::DecodePolicy;
use crate::cluster::workers::{
    AdmissionConfig, EngineSpec, Job, RealCluster, RealClusterConfig, RealSchedMode,
};
use crate::engine::mock::MockEngineConfig;
use crate::engine::sampler::Sampling;
use crate::scheduler::interval::IntervalConfig;
use crate::scheduler::pbaa::PbaaConfig;
use crate::scheduler::staggered::StaggeredConfig;
use std::time::Duration;

/// The decode-balance scenario (live Fig. 7): a fast mock cluster with a
/// multi-worker decode DP pool and a single prefill worker, so placement
/// order tracks submission order and the decode policy is the only
/// variable.
pub fn skewed_decode_cluster(policy: DecodePolicy, n_decode: u32) -> RealClusterConfig {
    let sc = StaggeredConfig {
        interval: IntervalConfig {
            t_default: 0.02,
            ..Default::default()
        },
        pbaa: PbaaConfig {
            n_limit: 10_000,
            ..Default::default()
        },
        ..Default::default()
    };
    RealClusterConfig {
        n_prefill: 1,
        n_decode,
        decode_batch: 16,
        c_chunk: 4096,
        mode: RealSchedMode::Staggered(sc),
        decode_policy: policy,
        sampling: Sampling::Greedy,
        seed: 11,
        engine: EngineSpec::Mock(MockEngineConfig {
            t_prefill_base: 0.001,
            t_prefill_per_token: 5e-6,
            t_decode_step: 0.002,
            chunk: 512,
            jitter: 0.0,
            kv_elems_per_token: 8,
        }),
        admission: AdmissionConfig {
            max_inflight: 1024,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Submit `n_jobs` with skewed output lengths: every `heavy_stride`-th job
/// generates `heavy_max_new` tokens, the rest `light_max_new`. Spaced
/// submissions keep placement order ≈ arrival order, which makes blind
/// round-robin's aliasing with the pool size reproducible.
pub fn submit_skewed_jobs(
    cluster: &RealCluster,
    n_jobs: u64,
    heavy_stride: u64,
    heavy_max_new: u32,
    light_max_new: u32,
) {
    for i in 0..n_jobs {
        let heavy = i % heavy_stride == 0;
        let max_new = if heavy { heavy_max_new } else { light_max_new };
        cluster.submit(Job::new(i, vec![7; 24], max_new));
        // Wide enough that a briefly stalled scheduler thread on a loaded
        // CI runner still sees one placement per cycle (order-preserving).
        std::thread::sleep(Duration::from_millis(6));
    }
}
