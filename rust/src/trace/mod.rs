//! Cross-process request tracing: TTFT stage decomposition.
//!
//! Every request's time-to-first-token is decomposed into a fixed vocabulary
//! of stages bounded by *marks* — point-in-time events stamped by whichever
//! process observes them (scheduler, prefill shard, decode shard, or the DES):
//!
//! ```text
//! Arrival ─ buffer_wait ─ Dispatch ─ sched_dispatch ─ PrefillRecv
//!         ─ prefill_queue ─ PrefillStart ─ prefill_exec ─ PrefillEnd
//!         ─ kv_transfer ─ KvCommit ─ decode_queue ─ FirstToken
//! ```
//!
//! Marks are *boundary timestamps*, not pre-computed durations, so the stage
//! durations telescope: their sum equals `FirstToken − Arrival` exactly, by
//! construction. Cross-process clock skew cannot break that invariant — a mark
//! that lands before its predecessor is clamped forward (and counted, so skew
//! stays observable as a diagnostic rather than corrupting the accounting).
//!
//! Shard-local clocks are aligned to the scheduler clock via the existing
//! heartbeat `Ping { t_us }`: the shard records `offset = sched_t − local_t`
//! at receipt, which is wrong by at most the one-way network delay (≈ RTT on
//! the loopback/LAN deployments this repo targets). Marks recorded before the
//! first ping, or while the bounded shard-side buffer is full, are *shed* and
//! counted — tracing never blocks or stalls the TTFT path.
//!
//! The collector serves two consumers: aggregate per-stage histograms
//! (`ttft_stages` in `STATS` / loadgen / sweep JSON) and, when retention is
//! enabled (`sbs serve --trace-out`), per-request records rendered as
//! Chrome/Perfetto `trace_event` JSON with one track per process.

use crate::json::Json;
use crate::metrics::LatencyRecorder;
use std::collections::HashMap;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;

/// A point-in-time trace event. The discriminants are the wire encoding
/// (`Frame::TraceSpans`); do not reorder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Mark {
    /// Request accepted by the scheduler (t_arrive).
    Arrival = 0,
    /// Scheduler released the request from the stagger buffer to a unit.
    Dispatch = 1,
    /// Prefill process pulled the dispatch off the wire.
    PrefillRecv = 2,
    /// Prefill engine began executing the request's pass (in-engine queue ends).
    PrefillStart = 3,
    /// Prefill pass finished; KV is ready to move.
    PrefillEnd = 4,
    /// KV committed at its decode destination (direct ack or relay reassembly).
    KvCommit = 5,
    /// First token observed by the scheduler — TTFT endpoint.
    FirstToken = 6,
    /// Request admitted into a decode engine (timeline instant, not a stage bound).
    DecodeAdmit = 7,
    /// Request fully completed (timeline instant; closes the per-request record).
    Done = 8,
}

/// Number of distinct [`Mark`] kinds.
pub const N_MARKS: usize = 9;

impl Mark {
    /// Decode a wire byte; `None` for unknown values.
    pub fn from_wire(b: u8) -> Option<Mark> {
        match b {
            0 => Some(Mark::Arrival),
            1 => Some(Mark::Dispatch),
            2 => Some(Mark::PrefillRecv),
            3 => Some(Mark::PrefillStart),
            4 => Some(Mark::PrefillEnd),
            5 => Some(Mark::KvCommit),
            6 => Some(Mark::FirstToken),
            7 => Some(Mark::DecodeAdmit),
            8 => Some(Mark::Done),
            _ => None,
        }
    }

    pub fn to_wire(self) -> u8 {
        self as u8
    }

    pub fn name(self) -> &'static str {
        match self {
            Mark::Arrival => "arrival",
            Mark::Dispatch => "dispatch",
            Mark::PrefillRecv => "prefill_recv",
            Mark::PrefillStart => "prefill_start",
            Mark::PrefillEnd => "prefill_end",
            Mark::KvCommit => "kv_commit",
            Mark::FirstToken => "first_token",
            Mark::DecodeAdmit => "decode_admit",
            Mark::Done => "done",
        }
    }
}

/// One mark on the wire: 8 (id) + 1 (mark) + 8 (t_us) + 4 (unit) = 21 bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceMark {
    /// Cluster-wide request id.
    pub id: u64,
    /// Which boundary this stamps.
    pub mark: Mark,
    /// Scheduler-clock microseconds (shard-side marks are offset-corrected
    /// before they leave the shard).
    pub t_us: u64,
    /// DP unit / prefill instance index within the emitting process.
    pub unit: u32,
}

/// Named TTFT stages, in order. Stage `i` spans `BOUNDS[i] → BOUNDS[i+1]`.
pub const STAGES: [&str; 6] = [
    "buffer_wait",
    "sched_dispatch",
    "prefill_queue",
    "prefill_exec",
    "kv_transfer",
    "decode_queue",
];

/// Boundary marks for the TTFT stages, in telescoping order.
const BOUNDS: [Mark; 7] = [
    Mark::Arrival,
    Mark::Dispatch,
    Mark::PrefillRecv,
    Mark::PrefillStart,
    Mark::PrefillEnd,
    Mark::KvCommit,
    Mark::FirstToken,
];

/// A recorded mark: when, and which track (process) stamped it.
#[derive(Debug, Clone, Copy)]
struct MarkRec {
    t_us: u64,
    track: u16,
    unit: u32,
}

/// All marks observed for one request.
#[derive(Debug, Clone)]
struct RequestTrace {
    id: u64,
    marks: [Option<MarkRec>; N_MARKS],
    finalized: bool,
}

impl RequestTrace {
    fn new(id: u64) -> Self {
        RequestTrace {
            id,
            marks: [None; N_MARKS],
            finalized: false,
        }
    }
}

/// Walk the stage boundaries for one request, clamping out-of-order marks
/// forward so durations telescope. Returns per-stage microseconds, the total
/// (`== first_token − arrival` exactly when both exist), and the worst clamp.
fn stage_walk(marks: &[Option<MarkRec>; N_MARKS]) -> Option<([u64; 6], u64, u64)> {
    let t0 = marks[Mark::Arrival as usize]?.t_us;
    marks[Mark::FirstToken as usize]?;
    let mut stages = [0u64; 6];
    let mut prev = t0;
    let mut worst_clamp = 0u64;
    for (i, stage) in stages.iter_mut().enumerate() {
        // Absent boundary: zero-length stage, absorbed by the next present one.
        let t = match marks[BOUNDS[i + 1] as usize] {
            Some(m) => m.t_us,
            None => prev,
        };
        if t < prev {
            worst_clamp = worst_clamp.max(prev - t);
        }
        let eff = t.max(prev);
        *stage = eff - prev;
        prev = eff;
    }
    Some((stages, prev - t0, worst_clamp))
}

#[derive(Default)]
struct CollectorInner {
    /// Track-name interner: index in `tracks` is the `MarkRec::track` id.
    tracks: Vec<String>,
    track_ids: HashMap<String, u16>,
    pending: HashMap<u64, RequestTrace>,
    /// Completed per-request records kept for Perfetto export.
    retained: Vec<RequestTrace>,
    stages: Option<[LatencyRecorder; 6]>,
    ttft: Option<LatencyRecorder>,
    finalized: u64,
    incomplete: u64,
    dropped: u64,
    skew_clamped: u64,
    skew_max_us: u64,
}

/// Upper bound on concurrently-pending request traces; new ids beyond this
/// are shed (counted in `dropped`) so a mark leak cannot grow without bound.
const PENDING_CAP: usize = 65_536;

/// Aggregates marks from every process into per-stage TTFT histograms and
/// (optionally) per-request records for Perfetto export. All methods take
/// `&self`; the collector is designed to be shared behind an `Arc`.
pub struct TraceCollector {
    inner: Mutex<CollectorInner>,
    /// Max completed request records kept for `--trace-out`; 0 = stats only.
    retain: usize,
}

impl TraceCollector {
    pub fn new(retain: usize) -> Self {
        let mk = || {
            let mut v = Vec::with_capacity(6);
            for s in STAGES {
                v.push(LatencyRecorder::new(s));
            }
            let arr: [LatencyRecorder; 6] = v.try_into().expect("6 stages");
            arr
        };
        TraceCollector {
            inner: Mutex::new(CollectorInner {
                stages: Some(mk()),
                ttft: Some(LatencyRecorder::new("ttft")),
                ..CollectorInner::default()
            }),
            retain,
        }
    }

    /// Stamp one mark with a scheduler-clock timestamp in seconds.
    pub fn mark(&self, track: &str, id: u64, mark: Mark, unit: u32, t_s: f64) {
        let t_us = (t_s.max(0.0) * 1e6) as u64;
        self.record(track, 0, &[TraceMark { id, mark, t_us, unit }]);
    }

    /// Ingest a batch of wire marks from `track` (a shard label), plus the
    /// shard-side shed count piggybacked on the frame.
    pub fn record(&self, track: &str, shed: u32, marks: &[TraceMark]) {
        let mut g = self.inner.lock().unwrap();
        g.dropped += shed as u64;
        let tid = match g.track_ids.get(track) {
            Some(&t) => t,
            None => {
                let t = g.tracks.len() as u16;
                g.tracks.push(track.to_string());
                g.track_ids.insert(track.to_string(), t);
                t
            }
        };
        for m in marks {
            if !g.pending.contains_key(&m.id) {
                if g.pending.len() >= PENDING_CAP {
                    g.dropped += 1;
                    continue;
                }
                g.pending.insert(m.id, RequestTrace::new(m.id));
            }
            let rec = g.pending.get_mut(&m.id).unwrap();
            // First write wins: when two observers stamp the same boundary
            // (e.g. `PrefillRecv` at wire receipt and again at the runner's
            // queue pop), the earlier — more accurate — stamp is kept.
            if rec.marks[m.mark as usize].is_none() {
                rec.marks[m.mark as usize] = Some(MarkRec {
                    t_us: m.t_us,
                    track: tid,
                    unit: m.unit,
                });
            }
            if m.mark == Mark::FirstToken && !rec.finalized {
                rec.finalized = true;
                if let Some((stages, total, clamp)) = stage_walk(&rec.marks) {
                    let sg = g.stages.as_mut().unwrap();
                    for (i, d) in stages.iter().enumerate() {
                        sg[i].record(*d as f64 * 1e-6);
                    }
                    g.ttft.as_mut().unwrap().record(total as f64 * 1e-6);
                    g.finalized += 1;
                    if clamp > 0 {
                        g.skew_clamped += 1;
                        g.skew_max_us = g.skew_max_us.max(clamp);
                    }
                }
            }
            if m.mark == Mark::Done {
                if let Some(done) = g.pending.remove(&m.id) {
                    if !done.finalized {
                        g.incomplete += 1;
                    } else if g.retained.len() < self.retain {
                        g.retained.push(done);
                    }
                }
            }
        }
    }

    /// Drop a request that terminated without a first token (rejected,
    /// evicted, failed): it will never finalize.
    pub fn discard(&self, id: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(rec) = g.pending.remove(&id) {
            if !rec.finalized {
                g.incomplete += 1;
            } else if g.retained.len() < self.retain {
                g.retained.push(rec);
            }
        }
    }

    /// Number of requests with a complete TTFT decomposition.
    pub fn finalized(&self) -> u64 {
        self.inner.lock().unwrap().finalized
    }

    /// Per-stage TTFT breakdown: `{requests, dropped, ..., ttft: {...},
    /// sum_mean_ms, stages: {name: {count, mean_ms, p50_ms, p99_ms, share}}}`.
    /// `share` is each stage's fraction of the summed stage means, so the
    /// stage with the dominant share is *where the TTFT lives*.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let sg = g.stages.as_ref().unwrap();
        let sum_mean_ms: f64 = sg.iter().map(|h| h.mean_ms()).sum();
        let mut stages = Vec::with_capacity(6);
        for (i, name) in STAGES.iter().enumerate() {
            let h = &sg[i];
            let share = if sum_mean_ms > 0.0 {
                h.mean_ms() / sum_mean_ms
            } else {
                0.0
            };
            stages.push((
                *name,
                Json::obj(vec![
                    ("count", Json::from(h.count())),
                    ("mean_ms", Json::from(h.mean_ms())),
                    ("p50_ms", Json::from(h.percentile_ms(50.0))),
                    ("p99_ms", Json::from(h.percentile_ms(99.0))),
                    ("share", Json::from(share)),
                ]),
            ));
        }
        let ttft = g.ttft.as_ref().unwrap();
        Json::obj(vec![
            ("requests", Json::from(g.finalized)),
            ("incomplete", Json::from(g.incomplete)),
            ("dropped", Json::from(g.dropped)),
            ("skew_clamped", Json::from(g.skew_clamped)),
            ("skew_max_ms", Json::from(g.skew_max_us as f64 * 1e-3)),
            (
                "ttft",
                Json::obj(vec![
                    ("count", Json::from(ttft.count())),
                    ("mean_ms", Json::from(ttft.mean_ms())),
                    ("p50_ms", Json::from(ttft.percentile_ms(50.0))),
                    ("p99_ms", Json::from(ttft.percentile_ms(99.0))),
                ]),
            ),
            ("sum_mean_ms", Json::from(sum_mean_ms)),
            ("stages", Json::obj(stages)),
        ])
    }

    /// Render retained per-request records as Chrome/Perfetto `trace_event`
    /// JSON: one `pid` per emitting process (track), stage spans as complete
    /// (`"X"`) events on the unit that *ended* the stage, `decode_admit` /
    /// `done` as instants.
    pub fn perfetto_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut events = Vec::new();
        for (tid, name) in g.tracks.iter().enumerate() {
            events.push(Json::obj(vec![
                ("name", Json::from("process_name")),
                ("ph", Json::from("M")),
                ("pid", Json::from(tid as u64 + 1)),
                ("tid", Json::from(0u64)),
                (
                    "args",
                    Json::obj(vec![("name", Json::from(name.clone()))]),
                ),
            ]));
        }
        let mut retained: Vec<&RequestTrace> = g.retained.iter().collect();
        retained.sort_by_key(|r| r.id);
        for rec in retained {
            let t0 = match rec.marks[Mark::Arrival as usize] {
                Some(m) => m.t_us,
                None => continue,
            };
            let mut prev = t0;
            for (i, stage) in STAGES.iter().enumerate() {
                // Attribute the span to the process/unit that stamped its end.
                let end = match rec.marks[BOUNDS[i + 1] as usize] {
                    Some(m) => m,
                    None => continue,
                };
                let eff = end.t_us.max(prev);
                events.push(Json::obj(vec![
                    ("name", Json::from(*stage)),
                    ("cat", Json::from("ttft")),
                    ("ph", Json::from("X")),
                    ("ts", Json::from(prev)),
                    ("dur", Json::from(eff - prev)),
                    ("pid", Json::from(end.track as u64 + 1)),
                    ("tid", Json::from(end.unit as u64)),
                    ("args", Json::obj(vec![("id", Json::from(rec.id))])),
                ]));
                prev = eff;
            }
            for inst in [Mark::DecodeAdmit, Mark::Done] {
                if let Some(m) = rec.marks[inst as usize] {
                    events.push(Json::obj(vec![
                        ("name", Json::from(inst.name())),
                        ("cat", Json::from("ttft")),
                        ("ph", Json::from("i")),
                        ("s", Json::from("t")),
                        ("ts", Json::from(m.t_us)),
                        ("pid", Json::from(m.track as u64 + 1)),
                        ("tid", Json::from(m.unit as u64)),
                        ("args", Json::obj(vec![("id", Json::from(rec.id))])),
                    ]));
                }
            }
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::from("ms")),
        ])
    }

    /// Write the Perfetto export to `path`. Returns the number of events.
    pub fn write_perfetto(&self, path: &Path) -> std::io::Result<usize> {
        let doc = self.perfetto_json();
        let n = match doc.get("traceEvents") {
            Some(Json::Arr(v)) => v.len(),
            _ => 0,
        };
        let mut f = std::fs::File::create(path)?;
        f.write_all(doc.dump().as_bytes())?;
        f.write_all(b"\n")?;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(id: u64, mark: Mark, t_us: u64) -> TraceMark {
        TraceMark {
            id,
            mark,
            t_us,
            unit: 0,
        }
    }

    fn full_request(c: &TraceCollector, id: u64, base: u64) {
        c.record(
            "sched",
            0,
            &[m(id, Mark::Arrival, base), m(id, Mark::Dispatch, base + 100)],
        );
        c.record(
            "prefill",
            0,
            &[
                m(id, Mark::PrefillRecv, base + 150),
                m(id, Mark::PrefillStart, base + 400),
                m(id, Mark::PrefillEnd, base + 2400),
            ],
        );
        c.record(
            "sched",
            0,
            &[
                m(id, Mark::KvCommit, base + 2900),
                m(id, Mark::FirstToken, base + 3000),
                m(id, Mark::Done, base + 9000),
            ],
        );
    }

    #[test]
    fn stages_telescope_to_exact_ttft() {
        let c = TraceCollector::new(16);
        for i in 0..10 {
            full_request(&c, i, 1_000_000 + i * 50_000);
        }
        let j = c.to_json();
        assert_eq!(j.f64_at(&["requests"]), Some(10.0));
        let sum = j.f64_at(&["sum_mean_ms"]).unwrap();
        let ttft = j.path(&["ttft", "mean_ms"]).and_then(|x| x.as_f64()).unwrap();
        assert!(
            (sum - ttft).abs() < 1e-9,
            "stage means must sum to ttft mean exactly: {sum} vs {ttft}"
        );
        // Every request had a 3000 us arrival→first_token window.
        assert!((ttft - 3.0).abs() < 1e-9, "ttft mean {ttft} != 3.0ms");
        let pq = j.path(&["stages", "prefill_queue", "mean_ms"]).and_then(|x| x.as_f64());
        assert_eq!(pq, Some(0.25));
    }

    #[test]
    fn missing_marks_collapse_into_the_next_stage() {
        let c = TraceCollector::new(0);
        // Relay path without prefill-shard marks: only scheduler boundaries.
        c.record(
            "sched",
            0,
            &[
                m(7, Mark::Arrival, 1000),
                m(7, Mark::Dispatch, 1500),
                m(7, Mark::FirstToken, 4000),
            ],
        );
        let j = c.to_json();
        assert_eq!(j.f64_at(&["requests"]), Some(1.0));
        let sum = j.f64_at(&["sum_mean_ms"]).unwrap();
        assert!((sum - 3.0).abs() < 1e-9, "sum {sum} != 3.0ms");
        // Absent bounds make their stages zero; decode_queue absorbs the rest.
        let dq = j.path(&["stages", "decode_queue", "mean_ms"]).and_then(|x| x.as_f64());
        assert_eq!(dq, Some(2.5));
        let pe = j.path(&["stages", "prefill_exec", "mean_ms"]).and_then(|x| x.as_f64());
        assert_eq!(pe, Some(0.0));
    }

    #[test]
    fn skewed_marks_are_clamped_and_counted_without_breaking_the_sum() {
        let c = TraceCollector::new(0);
        // PrefillRecv stamped *before* Dispatch (clock skew on the shard).
        c.record(
            "sched",
            0,
            &[m(3, Mark::Arrival, 10_000), m(3, Mark::Dispatch, 12_000)],
        );
        c.record("prefill", 0, &[m(3, Mark::PrefillRecv, 11_000)]);
        c.record("sched", 0, &[m(3, Mark::FirstToken, 15_000)]);
        let j = c.to_json();
        assert_eq!(j.f64_at(&["requests"]), Some(1.0));
        assert_eq!(j.f64_at(&["skew_clamped"]), Some(1.0));
        assert!(j.f64_at(&["skew_max_ms"]).unwrap() >= 0.999);
        let sum = j.f64_at(&["sum_mean_ms"]).unwrap();
        assert!((sum - 5.0).abs() < 1e-9, "clamped sum {sum} != 5.0ms");
    }

    #[test]
    fn shed_counts_and_discards_are_accounted() {
        let c = TraceCollector::new(0);
        c.record("shard", 42, &[]);
        c.record("sched", 0, &[m(9, Mark::Arrival, 100)]);
        c.discard(9);
        let j = c.to_json();
        assert_eq!(j.f64_at(&["dropped"]), Some(42.0));
        assert_eq!(j.f64_at(&["incomplete"]), Some(1.0));
        assert_eq!(j.f64_at(&["requests"]), Some(0.0));
    }

    #[test]
    fn perfetto_export_is_valid_trace_event_json() {
        let c = TraceCollector::new(16);
        full_request(&c, 1, 5_000);
        full_request(&c, 2, 6_000);
        let doc = c.perfetto_json();
        let parsed = crate::json::parse(&doc.dump()).expect("self-parse");
        let events = match parsed.get("traceEvents") {
            Some(Json::Arr(v)) => v,
            other => panic!("traceEvents missing: {other:?}"),
        };
        // 2 process_name metadata + per request: 6 stage spans + done instant.
        assert!(events.len() >= 2 + 2 * 7, "got {} events", events.len());
        let mut saw_meta = false;
        let mut span_dur_total = 0.0;
        for e in events {
            let ph = e.get("ph").and_then(|x| x.as_str()).unwrap().to_string();
            match ph.as_str() {
                "M" => {
                    saw_meta = true;
                    assert!(e.path(&["args", "name"]).is_some());
                }
                "X" => {
                    assert!(e.f64_at(&["ts"]).is_some() && e.f64_at(&["dur"]).is_some());
                    assert!(e.f64_at(&["pid"]).unwrap() >= 1.0);
                    span_dur_total += e.f64_at(&["dur"]).unwrap();
                }
                "i" => assert!(e.f64_at(&["ts"]).is_some()),
                other => panic!("unexpected ph {other}"),
            }
        }
        assert!(saw_meta, "process_name metadata missing");
        // Two requests, 3000 us of stage span each.
        assert!((span_dur_total - 6000.0).abs() < 1e-6);
    }

    #[test]
    fn retention_cap_bounds_the_perfetto_record_count() {
        let c = TraceCollector::new(1);
        full_request(&c, 1, 1_000);
        full_request(&c, 2, 2_000);
        let doc = c.perfetto_json();
        let events = match doc.get("traceEvents") {
            Some(Json::Arr(v)) => v.clone(),
            _ => vec![],
        };
        // Stats still cover both requests even though only one is retained.
        assert_eq!(c.finalized(), 2);
        let spans = events
            .iter()
            .filter(|e| e.get("ph").and_then(|x| x.as_str()) == Some("X"))
            .count();
        assert_eq!(spans, 6, "exactly one retained request's spans");
    }

    #[test]
    fn mark_wire_codes_round_trip() {
        for b in 0..N_MARKS as u8 {
            let mk = Mark::from_wire(b).expect("valid mark byte");
            assert_eq!(mk.to_wire(), b);
        }
        assert_eq!(Mark::from_wire(N_MARKS as u8), None);
        assert_eq!(Mark::from_wire(255), None);
    }
}
