//! Workload generation: arrival processes, token-length distributions,
//! shared-prefix structure, and JSONL trace record/replay.
//!
//! The paper's two evaluation workloads are provided as presets:
//! [`LengthDist::paper_short`] (0–3K input tokens, mean ≈ 1K; Fig. 6a /
//! Table 1) and [`LengthDist::paper_long`] (3K–64K, mean ≈ 6.7K; Fig. 6b),
//! plus the decode workload of §5.2.2 (input+output ≈ 2.5K).

pub mod loadgen;
pub mod sweep;
mod trace;

pub use trace::{read_trace, write_trace};

use crate::scheduler::{Request, SloClass};
use crate::util::Rng;

/// SLO-class probability weights, indexed by [`SloClass::rank`]. Parsed
/// from the CLI/sweep `interactive:0.2,standard:0.5,batch:0.3` syntax;
/// weights are normalized at draw time so they need not sum to 1.
pub fn parse_class_mix(s: &str) -> Result<[f64; 3], String> {
    let mut mix = [0.0; 3];
    for part in s.split(',').filter(|p| !p.is_empty()) {
        let (name, weight) = part
            .split_once(':')
            .ok_or_else(|| format!("class-mix entry '{part}' is not <class>:<weight>"))?;
        let c = SloClass::parse(name.trim())
            .ok_or_else(|| format!("unknown SLO class '{name}' in class mix"))?;
        let w: f64 = weight
            .trim()
            .parse()
            .map_err(|_| format!("bad weight '{weight}' for class '{name}'"))?;
        if !w.is_finite() || w < 0.0 {
            return Err(format!("weight for class '{name}' must be >= 0"));
        }
        mix[c.rank()] += w;
    }
    if mix.iter().sum::<f64>() <= 0.0 {
        return Err("class mix has no positive weight".into());
    }
    Ok(mix)
}

/// Render a mix back to the canonical `name:weight` CLI form.
pub fn class_mix_label(mix: &[f64; 3]) -> String {
    SloClass::ALL
        .iter()
        .map(|c| format!("{}:{}", c.name(), mix[c.rank()]))
        .collect::<Vec<_>>()
        .join(",")
}

/// Draw one class from (unnormalized) weights.
pub(crate) fn draw_class(mix: &[f64; 3], rng: &mut Rng) -> SloClass {
    let total: f64 = mix.iter().sum();
    let mut x = rng.f64() * total;
    for c in SloClass::ALL {
        x -= mix[c.rank()];
        if x < 0.0 {
            return c;
        }
    }
    SloClass::Batch
}

/// Token-length distribution.
#[derive(Debug, Clone)]
pub enum LengthDist {
    /// Every sample is `n`.
    Fixed(u32),
    /// Uniform integer in `[lo, hi]`.
    Uniform { lo: u32, hi: u32 },
    /// Log-normal (underlying `mu`/`sigma`) clamped to `[lo, hi]` —
    /// the right-skewed shape of production prompt lengths.
    LogNormal { mu: f64, sigma: f64, lo: u32, hi: u32 },
}

impl LengthDist {
    /// Paper Fig. 6(a) / Table 1 prompt lengths: 0–3K tokens, mean ≈ 1K.
    pub fn paper_short() -> Self {
        LengthDist::LogNormal {
            mu: 6.75,
            sigma: 0.75,
            lo: 16,
            hi: 3072,
        }
    }

    /// Paper Fig. 6(b) long-context lengths: 3K–64K tokens, mean ≈ 6.7K.
    pub fn paper_long() -> Self {
        LengthDist::LogNormal {
            mu: 8.55,
            sigma: 0.65,
            lo: 3072,
            hi: 65536,
        }
    }

    /// Paper §5.2.2 decode outputs: combined in+out ≈ 2.5K with in ≈ 2K,
    /// heavy-tailed (long generations pin KV for minutes).
    pub fn paper_decode_out() -> Self {
        LengthDist::LogNormal {
            mu: 5.9,
            sigma: 0.8,
            lo: 32,
            hi: 4096,
        }
    }

    /// Draw one length.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        match *self {
            LengthDist::Fixed(n) => n,
            LengthDist::Uniform { lo, hi } => rng.range_u64(lo as u64, hi as u64) as u32,
            LengthDist::LogNormal { mu, sigma, lo, hi } => {
                (rng.lognormal(mu, sigma).round() as u32).clamp(lo, hi)
            }
        }
    }

    /// Empirical mean over `n` draws (used for load calibration).
    pub fn empirical_mean(&self, seed: u64, n: usize) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| self.sample(&mut rng) as f64).sum::<f64>() / n as f64
    }
}

/// Request arrival process.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Poisson with the given rate (requests/second) — the paper's
    /// "uniformly arriving requests" (Markovian).
    Poisson { qps: f64 },
    /// Deterministic equal spacing (variance-free control case).
    Uniform { qps: f64 },
    /// Poisson modulated by a square wave: `qps` during bursts,
    /// `qps × trough` between them (models >100% peak-to-trough traffic
    /// variance, §4.1.1).
    SquareWave {
        qps: f64,
        trough: f64,
        period: f64,
    },
    /// Gamma(k = 0.25) gaps (CV 2): arrivals clump into bursts separated
    /// by lulls — the DES twin of the loadgen's `bursty` model, so sweep
    /// grid points mean the same thing in both modes.
    Bursty { qps: f64 },
    /// Pareto(α = 1.5) gaps: occasional very long quiet periods with
    /// dense clusters between them — the DES twin of the loadgen's
    /// `heavy-tail` model.
    HeavyTail { qps: f64 },
}

impl ArrivalProcess {
    /// Next inter-arrival gap at absolute time `t`.
    pub fn next_gap(&self, rng: &mut Rng, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { qps } => rng.exp(qps.max(1e-9)),
            ArrivalProcess::Uniform { qps } => 1.0 / qps.max(1e-9),
            ArrivalProcess::SquareWave { qps, trough, period } => {
                let phase = (t / period).fract();
                let rate = if phase < 0.5 { qps } else { qps * trough };
                rng.exp(rate.max(1e-9))
            }
            ArrivalProcess::Bursty { qps } => {
                // Gamma(k, θ) has mean kθ; k = 0.25 gives CV 1/√k = 2.
                const SHAPE: f64 = 0.25;
                rng.gamma(SHAPE, 1.0 / (SHAPE * qps.max(1e-9)))
            }
            ArrivalProcess::HeavyTail { qps } => {
                // Pareto(x_m, α) has mean αx_m/(α−1); solve x_m for 1/qps.
                const ALPHA: f64 = 1.5;
                rng.pareto((ALPHA - 1.0) / (ALPHA * qps.max(1e-9)), ALPHA)
            }
        }
    }

    /// Build a mean-rate-`qps` process from its sweep/CLI name.
    pub fn named(name: &str, qps: f64) -> Result<Self, String> {
        Ok(match name {
            "poisson" => ArrivalProcess::Poisson { qps },
            "uniform" => ArrivalProcess::Uniform { qps },
            "bursty" | "gamma" => ArrivalProcess::Bursty { qps },
            "heavy-tail" | "heavy_tail" | "pareto" => ArrivalProcess::HeavyTail { qps },
            other => return Err(format!("unknown arrival process '{other}'")),
        })
    }
}

/// Shared-prefix structure for cache-aware experiments.
#[derive(Debug, Clone)]
pub struct PrefixSpec {
    /// Number of distinct prefix groups (system prompts / sessions).
    pub groups: usize,
    /// Zipf exponent over group popularity.
    pub zipf_s: f64,
    /// Prefix length distribution (clamped to the sampled input length).
    pub prefix_len: LengthDist,
    /// Fraction of requests that carry a shared prefix at all.
    pub participation: f64,
}

/// Full workload specification.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Arrival process.
    pub arrivals: ArrivalProcess,
    /// Prompt length distribution.
    pub input_len: LengthDist,
    /// Output (decode) length distribution.
    pub output_len: LengthDist,
    /// Optional shared-prefix structure.
    pub prefix: Option<PrefixSpec>,
    /// Optional SLO-class mix (weights indexed by [`SloClass::rank`]).
    /// `None` leaves every request at the class-less default
    /// ([`SloClass::Standard`]) and draws nothing from the RNG, so
    /// legacy workloads are bit-identical.
    pub class_mix: Option<[f64; 3]>,
    /// Optional per-class completion deadlines, milliseconds after
    /// arrival, indexed by [`SloClass::rank`]; an entry `<= 0` leaves
    /// that class deadline-free. Applied deterministically from the
    /// drawn class — no RNG draws — so enabling it does not perturb the
    /// request stream (parity precondition for rescue on/off A-Bs).
    pub class_deadline_ms: Option<[f64; 3]>,
    /// Workload horizon in seconds.
    pub duration: f64,
    /// RNG seed (workloads are fully reproducible).
    pub seed: u64,
}

impl WorkloadSpec {
    /// Paper Fig. 6(a) workload at a given QPS.
    pub fn paper_short(qps: f64, duration: f64, seed: u64) -> Self {
        WorkloadSpec {
            arrivals: ArrivalProcess::Poisson { qps },
            input_len: LengthDist::paper_short(),
            output_len: LengthDist::Uniform { lo: 64, hi: 512 },
            prefix: None,
            class_mix: None,
            class_deadline_ms: None,
            duration,
            seed,
        }
    }

    /// Paper Fig. 6(b) long-context workload.
    pub fn paper_long(qps: f64, duration: f64, seed: u64) -> Self {
        WorkloadSpec {
            arrivals: ArrivalProcess::Poisson { qps },
            input_len: LengthDist::paper_long(),
            output_len: LengthDist::Uniform { lo: 64, hi: 512 },
            prefix: None,
            class_mix: None,
            class_deadline_ms: None,
            duration,
            seed,
        }
    }

    /// Paper §5.2.2 decode-focused workload (input ≈ 2K, heavy-tailed
    /// output; combined ≈ 2.5K).
    pub fn paper_decode(qps: f64, duration: f64, seed: u64) -> Self {
        WorkloadSpec {
            arrivals: ArrivalProcess::Poisson { qps },
            input_len: LengthDist::LogNormal {
                mu: 7.1,
                sigma: 1.0,
                lo: 64,
                hi: 16384,
            },
            output_len: LengthDist::paper_decode_out(),
            prefix: None,
            class_mix: None,
            class_deadline_ms: None,
            duration,
            seed,
        }
    }

    /// Materialize the full request sequence.
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += self.arrivals.next_gap(&mut rng, t);
            if t >= self.duration {
                break;
            }
            let input = self.input_len.sample(&mut rng);
            let output = self.output_len.sample(&mut rng).max(1);
            let mut r = Request::new(id, input, output, t);
            if let Some(mix) = &self.class_mix {
                r = r.with_class(draw_class(mix, &mut rng));
            }
            if let Some(dl) = &self.class_deadline_ms {
                let ms = dl[r.class.rank()];
                if ms > 0.0 {
                    r = r.with_deadline(t + ms / 1000.0);
                }
            }
            if let Some(p) = &self.prefix {
                if rng.chance(p.participation) {
                    let group = rng.zipf(p.groups, p.zipf_s) as u64;
                    let plen = p.prefix_len.sample(&mut rng).min(input);
                    r = r.with_prefix(group, plen);
                }
            }
            out.push(r);
            id += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_short_mean_near_1k() {
        let m = LengthDist::paper_short().empirical_mean(1, 50_000);
        assert!((850.0..1150.0).contains(&m), "mean {m}");
    }

    #[test]
    fn paper_long_mean_near_6_7k() {
        let m = LengthDist::paper_long().empirical_mean(2, 50_000);
        assert!((5800.0..7600.0).contains(&m), "mean {m}");
    }

    #[test]
    fn lengths_respect_bounds() {
        let d = LengthDist::paper_short();
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((16..=3072).contains(&x));
        }
    }

    #[test]
    fn poisson_rate_close() {
        let spec = WorkloadSpec::paper_short(50.0, 100.0, 7);
        let reqs = spec.generate();
        let rate = reqs.len() as f64 / 100.0;
        assert!((44.0..56.0).contains(&rate), "rate {rate}");
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(reqs.last().unwrap().arrival < 100.0);
    }

    #[test]
    fn deterministic_generation() {
        let a = WorkloadSpec::paper_short(20.0, 10.0, 42).generate();
        let b = WorkloadSpec::paper_short(20.0, 10.0, 42).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.input_tokens, y.input_tokens);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn square_wave_modulates_rate() {
        let p = ArrivalProcess::SquareWave {
            qps: 100.0,
            trough: 0.1,
            period: 10.0,
        };
        let mut rng = Rng::new(5);
        let burst: f64 = (0..1000).map(|_| p.next_gap(&mut rng, 1.0)).sum::<f64>() / 1000.0;
        let quiet: f64 = (0..1000).map(|_| p.next_gap(&mut rng, 6.0)).sum::<f64>() / 1000.0;
        assert!(quiet > burst * 5.0, "burst {burst} quiet {quiet}");
    }

    #[test]
    fn bursty_and_heavy_tail_preserve_mean_rate() {
        // Both models are mean-rate-preserving by construction; a long
        // horizon must recover the nominal rate within sampling noise
        // (heavy-tail has infinite variance at α = 1.5, so its band is
        // wide).
        for (name, lo, hi) in [("bursty", 40.0, 60.0), ("heavy-tail", 30.0, 70.0)] {
            let mut spec = WorkloadSpec::paper_short(50.0, 200.0, 11);
            spec.arrivals = ArrivalProcess::named(name, 50.0).unwrap();
            let rate = spec.generate().len() as f64 / 200.0;
            assert!((lo..hi).contains(&rate), "{name} rate {rate}");
        }
    }

    #[test]
    fn bursty_gaps_clump() {
        // CV 2 means the gap distribution is far more dispersed than the
        // exponential (CV 1) at the same mean.
        let mut rng = Rng::new(13);
        let cv = |p: &ArrivalProcess, rng: &mut Rng| {
            let gaps: Vec<f64> = (0..20_000).map(|_| p.next_gap(rng, 0.0)).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64;
            var.sqrt() / m
        };
        let cv_poisson = cv(&ArrivalProcess::Poisson { qps: 20.0 }, &mut rng);
        let cv_bursty = cv(&ArrivalProcess::Bursty { qps: 20.0 }, &mut rng);
        assert!(cv_bursty > cv_poisson * 1.5, "poisson {cv_poisson} bursty {cv_bursty}");
    }

    #[test]
    fn named_rejects_unknown() {
        assert!(ArrivalProcess::named("weibull", 1.0).is_err());
        assert!(ArrivalProcess::named("pareto", 1.0).is_ok());
    }

    #[test]
    fn class_mix_parses_and_round_trips() {
        let mix = parse_class_mix("interactive:0.2,standard:0.5,batch:0.3").unwrap();
        assert_eq!(mix, [0.2, 0.5, 0.3]);
        assert_eq!(
            class_mix_label(&mix),
            "interactive:0.2,standard:0.5,batch:0.3"
        );
        // Partial specs leave the rest at zero weight.
        assert_eq!(parse_class_mix("batch:1").unwrap(), [0.0, 0.0, 1.0]);
        assert!(parse_class_mix("premium:1").is_err());
        assert!(parse_class_mix("interactive:-1").is_err());
        assert!(parse_class_mix("interactive:0,batch:0").is_err());
    }

    #[test]
    fn class_mix_draws_match_weights() {
        let mut spec = WorkloadSpec::paper_short(100.0, 100.0, 17);
        spec.class_mix = Some([0.2, 0.5, 0.3]);
        let reqs = spec.generate();
        let mut counts = [0usize; 3];
        for r in &reqs {
            counts[r.class.rank()] += 1;
        }
        let n = reqs.len() as f64;
        for (got, want) in counts.iter().zip([0.2, 0.5, 0.3]) {
            let frac = *got as f64 / n;
            assert!((frac - want).abs() < 0.05, "{counts:?} vs weights");
        }
        // Same seed → same classes (parity precondition for DES vs live).
        let again = spec.generate();
        for (a, b) in reqs.iter().zip(&again) {
            assert_eq!(a.class, b.class);
        }
    }

    #[test]
    fn class_deadlines_derive_from_class_without_touching_the_rng() {
        let mut spec = WorkloadSpec::paper_short(100.0, 50.0, 17);
        spec.class_mix = Some([0.2, 0.5, 0.3]);
        let base = spec.generate();
        // Interactive gets 2s, standard none (0 = deadline-free), batch 60s.
        spec.class_deadline_ms = Some([2000.0, 0.0, 60_000.0]);
        let with = spec.generate();
        assert_eq!(base.len(), with.len());
        for (a, b) in base.iter().zip(&with) {
            // Deadlines must not perturb arrivals, lengths or classes.
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.input_tokens, b.input_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(a.class, b.class);
            match b.class {
                SloClass::Interactive => {
                    assert_eq!(b.deadline, Some(b.arrival + 2.0), "anchored at arrival")
                }
                SloClass::Standard => assert!(b.deadline.is_none()),
                SloClass::Batch => assert_eq!(b.deadline, Some(b.arrival + 60.0)),
            }
        }
    }

    #[test]
    fn classless_generation_unchanged_by_class_field() {
        // `class_mix: None` must not perturb the RNG stream.
        let base = WorkloadSpec::paper_short(20.0, 10.0, 42).generate();
        for r in &base {
            assert_eq!(r.class, SloClass::Standard);
            assert!(r.deadline.is_none());
        }
    }

    #[test]
    fn prefix_workload_attaches_groups() {
        let mut spec = WorkloadSpec::paper_short(50.0, 20.0, 9);
        spec.prefix = Some(PrefixSpec {
            groups: 8,
            zipf_s: 1.1,
            prefix_len: LengthDist::Uniform { lo: 100, hi: 600 },
            participation: 0.8,
        });
        let reqs = spec.generate();
        let with = reqs.iter().filter(|r| r.prefix_group.is_some()).count();
        let frac = with as f64 / reqs.len() as f64;
        assert!((0.7..0.9).contains(&frac), "participation {frac}");
        for r in &reqs {
            assert!(r.prefix_len <= r.input_tokens);
        }
    }
}
