//! Open-loop TCP load generator for the serving frontend (`sbs loadgen`).
//!
//! Arrivals follow the `--arrival` process at mean rate `--rate` over
//! `--duration` seconds, generated up front and timestamped against a
//! shared epoch — the *open-loop* discipline of the paper's evaluation
//! (and of Sarathi-style serving benchmarks): request N is due at its
//! scheduled instant whether or not earlier requests have completed.
//! `--conns` client connections drain the schedule; when all connections
//! are busy, later arrivals are sent late and the delay is charged to the
//! request's latency, so saturation shows up as growing TTFT rather than
//! a silently reduced offered rate.
//!
//! Three arrival models (all mean-rate-preserving, so reports stay
//! comparable across models):
//!
//! * `poisson` — exponential gaps, the classical memoryless baseline.
//! * `bursty` — Gamma(k=0.25) gaps (CV 2): arrivals clump into bursts
//!   separated by lulls, the regime that stresses batching windows.
//! * `heavy-tail` — Pareto(α=1.5) gaps: occasional very long quiet
//!   periods with dense arrival clusters between them.
//!
//! The report is JSON on stdout: offered/completed/`BUSY` counts, TTFT
//! and end-to-end latency summaries (mean, p50, p90, p99) measured from
//! the scheduled arrival instant, the arrival model used, and the
//! server's decode DP-pool gauges (per-DP occupancy + imbalance, fetched
//! via the `STATS` protocol command at the end of the run).

use crate::cli::Command;
use crate::json::Json;
use crate::metrics::LatencyRecorder;
use crate::scheduler::SloClass;
use crate::testing::net::{self, Reply};
use crate::util::Rng;
use anyhow::{anyhow, Context, Result};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Inter-arrival process for the open-loop schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalModel {
    /// Exponential gaps (memoryless baseline).
    Poisson,
    /// Gamma-burst gaps: CV 2, arrivals clump into bursts.
    Bursty,
    /// Pareto-tailed gaps: long lulls, dense clusters.
    HeavyTail,
}

impl ArrivalModel {
    /// Parse a `--arrival` value.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "poisson" => ArrivalModel::Poisson,
            "bursty" | "gamma" => ArrivalModel::Bursty,
            "heavy-tail" | "heavy_tail" | "pareto" => ArrivalModel::HeavyTail,
            other => return Err(anyhow!("unknown arrival model '{other}'")),
        })
    }

    /// Stable name for the JSON report.
    pub fn name(self) -> &'static str {
        match self {
            ArrivalModel::Poisson => "poisson",
            ArrivalModel::Bursty => "bursty",
            ArrivalModel::HeavyTail => "heavy-tail",
        }
    }

    /// Draw one inter-arrival gap with mean `1/rate` seconds.
    fn gap(self, rng: &mut Rng, rate: f64) -> f64 {
        let rate = rate.max(1e-9);
        match self {
            ArrivalModel::Poisson => rng.exp(rate),
            ArrivalModel::Bursty => {
                // Gamma(k, θ) has mean kθ; k = 0.25 gives CV 1/√k = 2.
                const SHAPE: f64 = 0.25;
                rng.gamma(SHAPE, 1.0 / (SHAPE * rate))
            }
            ArrivalModel::HeavyTail => {
                // Pareto(x_m, α) has mean αx_m/(α−1); solve x_m for 1/rate.
                const ALPHA: f64 = 1.5;
                rng.pareto((ALPHA - 1.0) / (ALPHA * rate), ALPHA)
            }
        }
    }
}

/// One scheduled request. Opaque outside this module: build schedules
/// with [`build_schedule`] and drain them with [`run_schedule`] (the
/// sweep harness's live mode drives the loadgen this way, in process).
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Due time, seconds from the epoch.
    at: f64,
    /// Prompt length in tokens (encoded as that many prompt bytes).
    prompt_tokens: u32,
    /// Generation budget.
    max_new: u32,
    /// SLO class sent on the `GEN` line (standard = class-less wire form).
    class: SloClass,
    /// Completion deadline sent as `deadline=<ms>` on the `GEN` line and
    /// scored client-side against the scheduled arrival instant.
    deadline_ms: Option<f64>,
}

/// Per-connection tallies, merged into the final report.
#[derive(Debug, Default)]
struct ClientStats {
    /// `(class, seconds)` TTFT samples — split per class at merge time.
    ttft: Vec<(SloClass, f64)>,
    e2e: Vec<f64>,
    completed: u64,
    busy: u64,
    /// `BUSY` replies per class (which traffic the server shed).
    busy_by_class: [u64; 3],
    /// Completions inside their deadline, per class (deadline-carrying
    /// requests only).
    deadline_met_by_class: [u64; 3],
    /// Completions past their deadline, per class.
    deadline_missed_by_class: [u64; 3],
    errors: u64,
    tokens: u64,
}

/// `sbs loadgen` entrypoint.
pub fn cli_loadgen(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sbs loadgen", "open-loop TCP load generator")
        .opt("addr", "server address", Some("127.0.0.1:7433"))
        .opt("rate", "offered load, requests/second", Some("20"))
        .opt("duration", "offered-load horizon, seconds", Some("10"))
        .opt("conns", "concurrent client connections", Some("8"))
        .opt("prompt-tokens", "prompt length per request", Some("48"))
        .opt("max-new", "tokens to generate per request", Some("16"))
        .opt(
            "arrival",
            "inter-arrival model: poisson | bursty | heavy-tail",
            Some("poisson"),
        )
        .opt(
            "class-mix",
            "SLO class weights, e.g. interactive:0.2,standard:0.5,batch:0.3 \
             (empty = every request class-less)",
            Some(""),
        )
        .opt(
            "class-deadline-ms",
            "per-class completion deadlines, e.g. interactive:800 \
             (sent as deadline=<ms> on the GEN line, scored from the \
             scheduled arrival; empty = no deadlines)",
            Some(""),
        )
        .opt("seed", "arrival-process seed", Some("42"))
        .opt(
            "wait-ready-secs",
            "readiness poll timeout for --wait-ready",
            Some("30"),
        )
        .flag(
            "wait-ready",
            "poll for the server's listen socket before offering load",
        )
        .flag("shutdown", "send SHUTDOWN to the server when finished");
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;
    let addr = args.str_or("addr", "127.0.0.1:7433");
    let rate: f64 = args.parse_or("rate", 20.0).map_err(|e| anyhow!("{e}"))?;
    let duration: f64 = args.parse_or("duration", 10.0).map_err(|e| anyhow!("{e}"))?;
    let conns: usize = args.parse_or("conns", 8).map_err(|e| anyhow!("{e}"))?;
    let prompt_tokens: u32 = args
        .parse_or("prompt-tokens", 48u32)
        .map_err(|e| anyhow!("{e}"))?;
    let max_new: u32 = args.parse_or("max-new", 16u32).map_err(|e| anyhow!("{e}"))?;
    let arrival = ArrivalModel::parse(&args.str_or("arrival", "poisson"))?;
    let class_mix_arg = args.str_or("class-mix", "");
    let class_mix = if class_mix_arg.is_empty() {
        None
    } else {
        Some(super::parse_class_mix(&class_mix_arg).map_err(|e| anyhow!("{e}"))?)
    };
    // Same `<class>:<value>` grammar as the mix; values are milliseconds
    // and 0 leaves that class deadline-free.
    let deadline_arg = args.str_or("class-deadline-ms", "");
    let class_deadline_ms = if deadline_arg.is_empty() {
        None
    } else {
        Some(super::parse_class_mix(&deadline_arg).map_err(|e| anyhow!("{e}"))?)
    };
    let seed: u64 = args.parse_or("seed", 42u64).map_err(|e| anyhow!("{e}"))?;

    if args.flag("wait-ready") {
        // Bounded poll instead of the caller guessing with `sleep`: the
        // run starts the moment the server binds, and a server that never
        // comes up fails fast with a clear error.
        let secs: u64 = args
            .parse_or("wait-ready-secs", 30u64)
            .map_err(|e| anyhow!("{e}"))?;
        net::wait_for_port(&addr, Duration::from_secs(secs))?;
    }

    let schedule = build_schedule(
        arrival,
        rate,
        duration,
        seed,
        prompt_tokens,
        max_new,
        class_mix,
        class_deadline_ms,
    );
    let offered = schedule.len();
    let report = run_schedule(&addr, schedule, conns)?;
    // Grab the server's decode-pool gauges before (optionally) draining it.
    let decode_pool = match fetch_stats(&addr) {
        Ok(j) => j,
        Err(e) => {
            log::warn!("could not fetch decode-pool stats: {e:#}");
            Json::Null
        }
    };
    if args.flag("shutdown") {
        send_shutdown(&addr)?;
    }

    let mut j = match report.to_json() {
        Json::Obj(m) => m,
        _ => unreachable!(),
    };
    j.insert("offered".into(), Json::from(offered));
    j.insert("rate_qps".into(), Json::from(rate));
    j.insert("duration_s".into(), Json::from(duration));
    j.insert("conns".into(), Json::from(conns));
    j.insert("arrival".into(), Json::from(arrival.name()));
    if let Some(mix) = &class_mix {
        j.insert("class_mix".into(), Json::from(super::class_mix_label(mix)));
    }
    // Per-class flow-control counters straight off the server's STATS:
    // who the admission controller throttled vs shed.
    for key in ["rejected_overload", "rejected_shed"] {
        if let Some(v) = decode_pool.get(key) {
            j.insert(key.into(), v.clone());
        }
    }
    // Hoist pool liveness to the top level: a shard killed mid-run —
    // decode *or* prefill — must be loud in the report, not a silently
    // smaller pool.
    for key in ["n_units", "units_alive"] {
        if let Some(v) = decode_pool.get(key) {
            j.insert(key.into(), v.clone());
        }
    }
    if let Some(p) = decode_pool.get("prefill") {
        if let Some(v) = p.get("n_units") {
            j.insert("prefill_n_units".into(), v.clone());
        }
        if let Some(v) = p.get("units_alive") {
            j.insert("prefill_units_alive".into(), v.clone());
        }
    }
    // Hoist the rescue gauges: the deadline-rescue CI gate reads
    // `rescue.preempted` / `rescue.migrated` / `rescue.rescue_deadline_met`
    // straight off the report.
    if let Some(v) = decode_pool.get("rescue") {
        j.insert("rescue".into(), v.clone());
    }
    // Hoist the per-stage TTFT decomposition and the ledger-divergence
    // counter: a sweep/CI gate reads `ttft_stages` straight off the
    // report, and divergence must be loud, not buried in the pool dump.
    for key in ["ttft_stages", "ledger_divergence"] {
        if let Some(v) = decode_pool.get(key) {
            j.insert(key.into(), v.clone());
        }
    }
    // Hoist the KV wire accounting too: the compression / direct-
    // transfer claims are asserted straight off the report.
    if let Some(kv) = decode_pool.get("kv_wire") {
        for (from, to) in [
            ("codec", "kv_wire_codec"),
            ("wire_bytes", "kv_wire_bytes"),
            ("raw_bytes", "kv_raw_bytes"),
            ("relay_wire_bytes", "kv_relay_wire_bytes"),
        ] {
            if let Some(v) = kv.get(from) {
                j.insert(to.into(), v.clone());
            }
        }
    }
    j.insert("decode_pool".into(), decode_pool);
    println!("{}", Json::Obj(j).dump());
    Ok(())
}

/// Aggregate loadgen outcome (the JSON report's source of truth).
#[derive(Debug)]
pub struct LoadgenReport {
    /// Requests answered with a full generation.
    pub completed: u64,
    /// Requests shed with `BUSY`.
    pub busy: u64,
    /// `BUSY` replies split by SLO class (indexed by [`SloClass::rank`]).
    pub busy_by_class: [u64; 3],
    /// Deadline-carrying completions inside their deadline, per class.
    pub deadline_met_by_class: [u64; 3],
    /// Deadline-carrying completions past their deadline, per class.
    pub deadline_missed_by_class: [u64; 3],
    /// Protocol/transport errors.
    pub errors: u64,
    /// Total streamed tokens.
    pub tokens: u64,
    /// Wall time of the whole run, seconds.
    pub elapsed_s: f64,
    /// TTFT from scheduled arrival.
    pub ttft: LatencyRecorder,
    /// TTFT split by SLO class (indexed by [`SloClass::rank`]).
    pub ttft_by_class: [LatencyRecorder; 3],
    /// End-to-end latency from scheduled arrival.
    pub e2e: LatencyRecorder,
}

impl LoadgenReport {
    /// JSON summary (includes p50/p99 TTFT via the recorders).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("completed", Json::from(self.completed)),
            ("busy", Json::from(self.busy)),
            ("errors", Json::from(self.errors)),
            ("tokens", Json::from(self.tokens)),
            ("elapsed_s", Json::from(self.elapsed_s)),
            (
                "achieved_qps",
                Json::from(self.completed as f64 / self.elapsed_s.max(1e-9)),
            ),
            (
                "decode_tps",
                Json::from(self.tokens as f64 / self.elapsed_s.max(1e-9)),
            ),
            ("ttft", self.ttft.to_json()),
            (
                "ttft_by_class",
                Json::obj(
                    SloClass::ALL
                        .iter()
                        .map(|c| (c.name(), self.ttft_by_class[c.rank()].to_json()))
                        .collect(),
                ),
            ),
            (
                "busy_by_class",
                Json::obj(
                    SloClass::ALL
                        .iter()
                        .map(|c| (c.name(), Json::from(self.busy_by_class[c.rank()])))
                        .collect(),
                ),
            ),
            (
                "deadline_by_class",
                Json::obj(
                    SloClass::ALL
                        .iter()
                        .map(|c| {
                            (
                                c.name(),
                                Json::obj(vec![
                                    (
                                        "met",
                                        Json::from(self.deadline_met_by_class[c.rank()]),
                                    ),
                                    (
                                        "missed",
                                        Json::from(self.deadline_missed_by_class[c.rank()]),
                                    ),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            ("e2e", self.e2e.to_json()),
        ])
    }
}

/// Materialize the arrival schedule under the chosen inter-arrival model.
/// With a class mix, classes are drawn from the same seeded stream as the
/// gaps — the schedule is a deterministic function of `(model, seed)`, so
/// a DES replay of the identical trace sees the identical class sequence.
#[allow(clippy::too_many_arguments)]
pub fn build_schedule(
    model: ArrivalModel,
    rate: f64,
    duration: f64,
    seed: u64,
    prompt_tokens: u32,
    max_new: u32,
    class_mix: Option<[f64; 3]>,
    class_deadline_ms: Option<[f64; 3]>,
) -> VecDeque<Arrival> {
    let mut rng = Rng::new(seed);
    let mut out = VecDeque::new();
    let mut t = 0.0;
    loop {
        t += model.gap(&mut rng, rate);
        if t >= duration {
            break;
        }
        let class = match &class_mix {
            Some(mix) => super::draw_class(mix, &mut rng),
            None => SloClass::Standard,
        };
        // Deadlines derive from the drawn class with no RNG draws, so a
        // rescue on/off A-B over the same seed offers an identical
        // schedule.
        let deadline_ms = class_deadline_ms
            .map(|dl| dl[class.rank()])
            .filter(|ms| *ms > 0.0);
        out.push_back(Arrival {
            at: t,
            prompt_tokens,
            max_new,
            class,
            deadline_ms,
        });
    }
    out
}

/// Drain a schedule against a running server and return the latency
/// report. Public so embedders (the sweep harness's live mode) can drive
/// the open-loop discipline without shelling out.
pub fn run_schedule(addr: &str, schedule: VecDeque<Arrival>, conns: usize) -> Result<LoadgenReport> {
    let queue = Arc::new(Mutex::new(schedule));
    let t0 = Instant::now();
    let mut workers = Vec::new();
    for _ in 0..conns.max(1) {
        let queue = queue.clone();
        let addr = addr.to_string();
        workers.push(std::thread::spawn(move || run_client(&addr, t0, queue)));
    }
    let mut ttft = LatencyRecorder::new("ttft");
    let mut ttft_by_class = SloClass::ALL.map(|c| LatencyRecorder::new(c.name()));
    let mut e2e = LatencyRecorder::new("e2e");
    let mut completed = 0;
    let mut busy = 0;
    let mut busy_by_class = [0u64; 3];
    let mut deadline_met_by_class = [0u64; 3];
    let mut deadline_missed_by_class = [0u64; 3];
    let mut errors = 0;
    let mut tokens = 0;
    for w in workers {
        match w.join() {
            Ok(st) => {
                for (class, x) in st.ttft {
                    ttft.record(x);
                    ttft_by_class[class.rank()].record(x);
                }
                for x in st.e2e {
                    e2e.record(x);
                }
                completed += st.completed;
                busy += st.busy;
                for (total, n) in busy_by_class.iter_mut().zip(st.busy_by_class) {
                    *total += n;
                }
                for (total, n) in deadline_met_by_class
                    .iter_mut()
                    .zip(st.deadline_met_by_class)
                {
                    *total += n;
                }
                for (total, n) in deadline_missed_by_class
                    .iter_mut()
                    .zip(st.deadline_missed_by_class)
                {
                    *total += n;
                }
                errors += st.errors;
                tokens += st.tokens;
            }
            Err(_) => errors += 1,
        }
    }
    Ok(LoadgenReport {
        completed,
        busy,
        busy_by_class,
        deadline_met_by_class,
        deadline_missed_by_class,
        errors,
        tokens,
        elapsed_s: t0.elapsed().as_secs_f64(),
        ttft,
        ttft_by_class,
        e2e,
    })
}

/// Drive one connection until the shared schedule is empty. Errors are
/// recorded, not propagated: stats gathered before a failure stay in the
/// report (losing them would skew the percentiles the tool exists to
/// measure).
fn run_client(addr: &str, t0: Instant, queue: Arc<Mutex<VecDeque<Arrival>>>) -> ClientStats {
    let mut st = ClientStats::default();
    let setup = || -> Result<(BufReader<TcpStream>, TcpStream)> {
        let conn = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        // A wedged server should fail the run, not hang it; tiny TOK
        // lines need TCP_NODELAY for honest latency numbers.
        conn.set_read_timeout(Some(Duration::from_secs(600)))?;
        conn.set_nodelay(true)?;
        Ok((BufReader::new(conn.try_clone()?), conn))
    };
    let (mut reader, mut out) = match setup() {
        Ok(x) => x,
        Err(e) => {
            log::error!("loadgen client: {e:#}");
            st.errors += 1;
            return st;
        }
    };
    let mut line = String::new();
    'arrivals: loop {
        let next = queue.lock().unwrap().pop_front();
        let Some(a) = next else { break };
        let now = t0.elapsed().as_secs_f64();
        if a.at > now {
            std::thread::sleep(Duration::from_secs_f64(a.at - now));
        }
        // One prompt byte per token (plus BOS server-side).
        let prompt = "x".repeat(a.prompt_tokens.max(1) as usize);
        // Standard stays class-less so legacy servers see the exact
        // pre-SLO wire line; annotations are added only when carried.
        let mut ann = String::new();
        if a.class != SloClass::Standard {
            ann.push_str(&format!(" class={}", a.class.name()));
        }
        if let Some(ms) = a.deadline_ms {
            ann.push_str(&format!(" deadline={ms}"));
        }
        let sent = writeln!(out, "GEN {}{} {}", a.max_new, ann, prompt);
        if let Err(e) = sent {
            log::error!("loadgen client: send failed: {e}");
            st.errors += 1;
            return st;
        }
        // TTFT is staged and only recorded on DONE: a stream that is cut
        // short (mid-generation rejection) must not contribute latency
        // samples for a request that never completed.
        let mut ttft_sample = None;
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) => {
                    log::error!("loadgen client: server closed the connection mid-request");
                    st.errors += 1;
                    return st;
                }
                Ok(_) => {}
                Err(e) => {
                    log::error!("loadgen client: recv failed: {e}");
                    st.errors += 1;
                    return st;
                }
            }
            match net::parse_reply(line.trim()) {
                Reply::Tok { .. } => {
                    if ttft_sample.is_none() {
                        ttft_sample = Some(t0.elapsed().as_secs_f64() - a.at);
                    }
                    st.tokens += 1;
                }
                Reply::Done { .. } => {
                    if let Some(x) = ttft_sample {
                        st.ttft.push((a.class, x));
                    }
                    let e2e = t0.elapsed().as_secs_f64() - a.at;
                    st.e2e.push(e2e);
                    st.completed += 1;
                    // Deadline scored against the *scheduled* arrival:
                    // queueing delay from a saturated client pool counts
                    // against the deadline, as it would for a real user.
                    if let Some(ms) = a.deadline_ms {
                        if e2e * 1e3 <= ms {
                            st.deadline_met_by_class[a.class.rank()] += 1;
                        } else {
                            st.deadline_missed_by_class[a.class.rank()] += 1;
                        }
                    }
                    break;
                }
                Reply::Busy { .. } => {
                    st.busy += 1;
                    st.busy_by_class[a.class.rank()] += 1;
                    break;
                }
                // Never sent during a GEN stream; ignore defensively.
                Reply::Stats { .. } => {}
                Reply::Err(_) => {
                    st.errors += 1;
                    break;
                }
                Reply::Bye => {
                    st.errors += 1;
                    break 'arrivals;
                }
            }
        }
    }
    // Per-connection close; the server keeps running.
    let _ = writeln!(out, "QUIT");
    st
}

/// Open a throwaway connection and fetch the server's decode DP-pool
/// gauges (`STATS` protocol command) as parsed JSON.
pub fn fetch_stats(addr: &str) -> Result<Json> {
    let mut conn = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    conn.set_read_timeout(Some(Duration::from_secs(30)))?;
    writeln!(conn, "STATS")?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    // One shared wire-format decoder (testing::net) for all clients.
    let Reply::Stats { json } = net::parse_reply(line.trim()) else {
        return Err(anyhow!("unexpected STATS reply: {line:?}"));
    };
    let parsed = crate::json::parse(&json).map_err(|e| anyhow!("bad STATS JSON: {e:?}"))?;
    let _ = writeln!(conn, "QUIT");
    Ok(parsed)
}

/// Open a throwaway connection and ask the server to drain and exit.
pub fn send_shutdown(addr: &str) -> Result<()> {
    let mut conn = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    writeln!(conn, "SHUTDOWN")?;
    // Wait for the BYE (or close) so the server definitely saw it.
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    Ok(())
}
