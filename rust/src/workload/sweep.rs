//! Replicated parameter-sweep experiments over the DES (and optionally
//! the live mock cluster), emitting versioned `BENCH_*.json` perf
//! trajectories (`sbs sweep`).
//!
//! The grid is declarative: every axis is a comma list (scheduler mode,
//! arrival process, decode placement policy, offered QPS, static stagger
//! window, decode KV budget, live KV wire codec) and the harness runs the
//! cartesian product, `--replicas` seeded runs per point. Replication uses
//! *common random numbers*: replica `r` runs at `seed + r` in **every**
//! grid point, so point-to-point deltas are paired comparisons rather
//! than fresh draws, and the whole DES document is byte-identical across
//! invocations (virtual time, sorted JSON keys, no wall-clock stamps).
//!
//! Poisson points additionally carry an M/M/1 sanity column (after the
//! queue-theoretic baselines of arXiv 2508.01002): the prefill pool is
//! collapsed to one Markovian server whose token service rate comes from
//! the DES cost model, predicting
//! `TTFT ≈ 1/(μ − λ) + t_pass + l_net`. It deliberately ignores batching
//! and DP structure — it validates the *trend* of the DES (finite and
//! same order below saturation, diverging as ρ → 1), not the exact value.
//!
//! `--compare old.json new.json` is the regression primitive used by the
//! CI bench gate: per matching grid point and metric it flags changes in
//! the "worse" direction that exceed both a relative floor and a
//! noise-aware threshold (σ × combined standard error of the replica
//! means), so single-replica jitter does not fail builds.

use crate::cli::Command;
use crate::cluster::costmodel::{DpPassLoad, PrefillCostModel};
use crate::cluster::dispatch::RescueConfig;
use crate::cluster::sim::{DecodePlacement, SchedMode, SimTopology, Simulation};
use crate::cluster::workers::{EngineSpec, RealClusterConfig, RealSchedMode};
use crate::config;
use crate::engine::mock::MockEngineConfig;
use crate::json::Json;
use crate::scheduler::baseline::ImmediatePolicy;
use crate::scheduler::decode::DecodeSchedConfig;
use crate::testing::net::TestServer;
use crate::transport::KvCodec;
use crate::scheduler::SloClass;
use crate::util::stats;
use crate::workload::{
    class_mix_label, loadgen, parse_class_mix, ArrivalProcess, LengthDist, WorkloadSpec,
};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

/// Document schema identifier (the `schema` field of every emitted file).
pub const SCHEMA_NAME: &str = "sbs-sweep-bench";

/// Schema version; bump on any breaking change to the document layout and
/// teach [`validate`] the migration.
pub const SCHEMA_VERSION: u64 = 1;

/// Metrics summarized (mean/std/min/max over replicas) per grid point.
pub const SUMMARY_METRICS: &[&str] = &[
    "ttft_p50_ms",
    "ttft_p99_ms",
    "ttft_mean_ms",
    "decode_tps",
    "imbalance",
    "kv_bytes",
];

/// Per-replica numeric fields every document must carry.
const REPLICA_FIELDS: &[&str] = &[
    "seed",
    "ttft_p50_ms",
    "ttft_p99_ms",
    "ttft_mean_ms",
    "decode_tps",
    "imbalance",
    "kv_bytes",
    "completed",
    "offered",
    "rejected",
];

/// Compared metrics with their direction of badness.
const COMPARE_METRICS: &[(&str, bool)] = &[
    // (metric, higher_is_worse)
    ("ttft_p50_ms", true),
    ("ttft_p99_ms", true),
    ("imbalance", true),
    ("decode_tps", false),
];

/// Declarative sweep grid: each axis is a list of values and the harness
/// runs the cartesian product (with the stagger-window axis collapsed
/// under the immediate baseline, where it has no meaning).
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Scheduler modes: `staggered` | `immediate`.
    pub scheds: Vec<String>,
    /// Arrival processes: `poisson` | `bursty` | `heavy-tail` | `uniform`.
    pub arrivals: Vec<String>,
    /// Decode placement policies: `load-aware` | `round-robin` | `random`.
    pub policies: Vec<String>,
    /// Offered rates (requests/second).
    pub qps: Vec<f64>,
    /// Static stagger windows in seconds; 0 = the adaptive Algorithm 1
    /// controller (the paper default), > 0 = the static-interval ablation
    /// at that `T_default`.
    pub windows: Vec<f64>,
    /// Per-DP decode KV-token budgets.
    pub kv_budgets: Vec<u64>,
    /// KV wire codecs (`raw` | `fp16` | `lz`). Fans out live-mode points
    /// only; the DES models the handoff analytically and ignores it.
    pub codecs: Vec<String>,
    /// Local decode pool sizes (`n_decode` DP units in-process). Fans out
    /// live-mode points only (the DES topology is fixed by the paper's
    /// Fig. 6(a)); scaling this axis is how handoff/TTFT tails are judged
    /// as the pool grows. With `--live-remote-decode` the pool comes from
    /// the listed shard processes instead and this axis merely labels the
    /// point. Reported as `local_pool_units` in the document.
    pub shards: Vec<u32>,
    /// SLO class mixes (`;`-separated on the CLI, since a mix itself is a
    /// comma list). `"none"` (or empty) = class-less traffic, and the
    /// point's params carry no `class_mix` key at all — so legacy
    /// baselines (`BENCH_7` and earlier) keep indexing the same points
    /// under `--compare`. Classed points add per-class TTFT/shed replica
    /// columns on top of the standard set.
    pub class_mixes: Vec<String>,
    /// Rescue axis: `off` | `on` (SLO-violation decode rescue —
    /// preemption + migration). `off` points carry no `rescue` param key
    /// at all, so legacy baselines keep indexing the same points under
    /// `--compare`; `on` points add rescue-counter replica columns.
    pub rescues: Vec<String>,
    /// Per-class completion deadlines in ms (class-mix grammar; `None` =
    /// deadline-free traffic). A scalar knob, not an axis: it applies to
    /// every point identically, so a rescue on/off pair over the same
    /// seed is a paired comparison over byte-identical workloads.
    pub class_deadline_ms: Option<[f64; 3]>,
    /// Seeded runs per grid point.
    pub replicas: u32,
    /// Base seed; replica `r` runs at `seed + r` in every point.
    pub seed: u64,
    /// Offered-load horizon per replica (virtual seconds in DES mode,
    /// wall seconds in live mode).
    pub duration: f64,
    /// Metrics warmup cut, seconds (DES mode).
    pub warmup: f64,
}

impl Default for SweepGrid {
    /// The quick CI grid. The checked-in `BENCH_9.json` baseline is this
    /// grid with `--live --shards 2,16` on top (its DES points are
    /// therefore directly comparable against `sbs sweep` with no axis
    /// flags, and its live points carry the shard-count axis). The
    /// class-less points are the same grid points `BENCH_7` carried, so
    /// cross-baseline `--compare` still overlaps on them.
    fn default() -> Self {
        SweepGrid {
            scheds: vec!["staggered".into(), "immediate".into()],
            arrivals: vec!["poisson".into(), "bursty".into()],
            policies: vec!["load-aware".into()],
            qps: vec![100.0],
            windows: vec![0.0],
            kv_budgets: vec![config::LIVE_KV_BUDGET_TOKENS],
            codecs: vec!["raw".into()],
            shards: vec![2],
            class_mixes: vec!["none".into(), "interactive:0.2,standard:0.5,batch:0.3".into()],
            rescues: vec!["off".into()],
            class_deadline_ms: None,
            replicas: 3,
            seed: 1,
            duration: 45.0,
            warmup: 10.0,
        }
    }
}

impl SweepGrid {
    /// JSON echo of the grid (embedded in every document).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sched", Json::from(self.scheds.clone())),
            ("arrival", Json::from(self.arrivals.clone())),
            ("decode_policy", Json::from(self.policies.clone())),
            ("qps", Json::from(self.qps.clone())),
            ("stagger_window_s", Json::from(self.windows.clone())),
            ("kv_budget_tokens", Json::from(self.kv_budgets.clone())),
            ("kv_wire", Json::from(self.codecs.clone())),
            (
                "local_pool_units",
                Json::Arr(self.shards.iter().map(|&s| Json::from(s)).collect()),
            ),
            ("class_mix", Json::from(self.class_mixes.clone())),
            ("rescue", Json::from(self.rescues.clone())),
            (
                "class_deadline_ms",
                match self.class_deadline_ms {
                    Some(dl) => Json::Arr(dl.iter().map(|&x| Json::from(x)).collect()),
                    None => Json::Null,
                },
            ),
            ("replicas", Json::from(self.replicas)),
            ("seed", Json::from(self.seed)),
            ("duration_s", Json::from(self.duration)),
            ("warmup_s", Json::from(self.warmup)),
        ])
    }
}

/// What to run for each grid point.
#[derive(Debug, Clone)]
pub struct SweepModes {
    /// Identifier stamped into the document (`BENCH_6`, ...).
    pub bench_id: String,
    /// Run the discrete-event simulator (deterministic, virtual time).
    pub des: bool,
    /// Also run each point against a live in-process mock cluster.
    pub live: Option<LiveOpts>,
}

/// Live-mode knobs (the DES axes map 1:1; these cover what only exists
/// on the live path).
#[derive(Debug, Clone)]
pub struct LiveOpts {
    /// Pre-started `sbs worker --decode` shard addresses. When non-empty
    /// the live cluster runs with no local decode workers, the KV handoff
    /// crosses real sockets, and the codec axis becomes measurable.
    pub remote_decode: Vec<String>,
    /// Prompt length per request.
    pub prompt_tokens: u32,
    /// Generation budget per request.
    pub max_new: u32,
    /// Loadgen client connections.
    pub conns: usize,
}

/// One expanded grid point.
#[derive(Debug, Clone)]
struct PointParams {
    mode: &'static str,
    sched: String,
    arrival: String,
    policy: String,
    qps: f64,
    window: f64,
    kv_budget: u64,
    /// Live points only; the DES ignores the codec axis.
    codec: Option<String>,
    /// Live points only; the DES topology is fixed. Sizes the in-process
    /// decode pool (`local_pool_units` in the document).
    shards: Option<u32>,
    /// Canonical class-mix label; `None` = class-less point (legacy
    /// param key set, comparable against pre-SLO baselines).
    class_mix: Option<String>,
    /// SLO-violation rescue enabled for this point. `false` keeps the
    /// legacy param key set (no `rescue` key at all).
    rescue: bool,
}

impl PointParams {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("mode", Json::from(self.mode)),
            ("sched", Json::from(self.sched.as_str())),
            ("arrival", Json::from(self.arrival.as_str())),
            ("policy", Json::from(self.policy.as_str())),
            ("qps", Json::from(self.qps)),
            ("stagger_window_s", Json::from(self.window)),
            ("kv_budget_tokens", Json::from(self.kv_budget)),
        ];
        if let Some(c) = &self.codec {
            pairs.push(("kv_wire", Json::from(c.as_str())));
        }
        if let Some(s) = self.shards {
            pairs.push(("local_pool_units", Json::from(s)));
        }
        if let Some(m) = &self.class_mix {
            pairs.push(("class_mix", Json::from(m.as_str())));
        }
        if self.rescue {
            pairs.push(("rescue", Json::from("on")));
        }
        Json::obj(pairs)
    }

    /// Parsed class weights, when the point is classed.
    fn mix(&self) -> Result<Option<[f64; 3]>> {
        self.class_mix
            .as_deref()
            .map(|m| parse_class_mix(m).map_err(|e| anyhow!(e)))
            .transpose()
    }
}

fn parse_policy(name: &str) -> Result<DecodePlacement> {
    Ok(match name {
        "load-aware" | "iqr" => DecodePlacement::IqrLex(DecodeSchedConfig::default()),
        "deadline-aware" | "deadline_aware" => {
            DecodePlacement::DeadlineAware(DecodeSchedConfig::default())
        }
        "round-robin" | "round_robin" => DecodePlacement::RoundRobin,
        "random" => DecodePlacement::Random,
        other => return Err(anyhow!("unknown decode policy '{other}'")),
    })
}

/// Expand the grid into points for one run mode, validating every axis
/// value up front so a typo fails before hours of simulation.
fn expand(grid: &SweepGrid, mode: &'static str) -> Result<Vec<PointParams>> {
    let mut out = Vec::new();
    for sched in &grid.scheds {
        if sched != "staggered" && sched != "immediate" {
            return Err(anyhow!("unknown scheduler mode '{sched}'"));
        }
        for arrival in &grid.arrivals {
            ArrivalProcess::named(arrival, 1.0).map_err(|e| anyhow!(e))?;
            for policy in &grid.policies {
                parse_policy(policy)?;
                for &qps in &grid.qps {
                    for (wi, &window) in grid.windows.iter().enumerate() {
                        // The window axis only means something under the
                        // staggered scheduler; collapse it (first value,
                        // recorded as 0) for the immediate baseline so
                        // the product holds no duplicate points.
                        if sched == "immediate" && wi > 0 {
                            continue;
                        }
                        let window = if sched == "immediate" { 0.0 } else { window };
                        for &kv_budget in &grid.kv_budgets {
                            for mix in &grid.class_mixes {
                                // Normalize through the parser so the same
                                // mix always indexes the same grid point.
                                let class_mix = if mix.is_empty() || mix == "none" {
                                    None
                                } else {
                                    Some(class_mix_label(
                                        &parse_class_mix(mix).map_err(|e| anyhow!(e))?,
                                    ))
                                };
                                for resc in &grid.rescues {
                                    let rescue = match resc.as_str() {
                                        "on" => true,
                                        "off" => false,
                                        other => {
                                            return Err(anyhow!(
                                                "unknown rescue value '{other}' (want on|off)"
                                            ))
                                        }
                                    };
                                    let base = PointParams {
                                        mode,
                                        sched: sched.clone(),
                                        arrival: arrival.clone(),
                                        policy: policy.clone(),
                                        qps,
                                        window,
                                        kv_budget,
                                        codec: None,
                                        shards: None,
                                        class_mix: class_mix.clone(),
                                        rescue,
                                    };
                                    if mode == "live" {
                                        for codec in &grid.codecs {
                                            KvCodec::parse(codec).ok_or_else(|| {
                                                anyhow!("unknown kv codec '{codec}'")
                                            })?;
                                            for &shards in &grid.shards {
                                                if shards == 0 {
                                                    return Err(anyhow!(
                                                        "--shards values must be >= 1"
                                                    ));
                                                }
                                                out.push(PointParams {
                                                    codec: Some(codec.clone()),
                                                    shards: Some(shards),
                                                    ..base.clone()
                                                });
                                            }
                                        }
                                    } else {
                                        out.push(base);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

/// One DES replica: the Fig. 6(a) topology with the point's knobs
/// applied, run to drain (bounded by `5 × duration + 60` virtual
/// seconds — a saturated point surfaces as `completed < offered`, it
/// does not hang the sweep).
fn run_des_replica(p: &PointParams, grid: &SweepGrid, seed: u64) -> Result<Json> {
    let staggered = p.sched == "staggered";
    let mut cfg = config::fig6a(1.0, staggered, seed);
    cfg.workload = WorkloadSpec::paper_short(p.qps, grid.duration, seed);
    cfg.workload.arrivals = ArrivalProcess::named(&p.arrival, p.qps).map_err(|e| anyhow!(e))?;
    cfg.warmup = grid.warmup;
    cfg.max_time = grid.duration * 5.0 + 60.0;
    cfg.decode = parse_policy(&p.policy)?;
    cfg.decode_caps.kv_max = p.kv_budget;
    if staggered && p.window > 0.0 {
        if let SchedMode::Staggered(sc) = &mut cfg.mode {
            sc.interval.t_default = p.window;
            sc.interval.adaptive = false;
        }
    }
    cfg.workload.class_mix = p.mix()?;
    cfg.workload.class_deadline_ms = grid.class_deadline_ms;
    if p.rescue {
        cfg.rescue = RescueConfig::on();
    }
    let r = Simulation::run(&cfg);
    // Modelled KV handoff traffic: every computed prefill token ships a
    // raw-f32 block sized like the mock engine's KV (16 elems × 4 B).
    // The live path reports measured wire bytes under the same key.
    let kv_bytes = r.report.throughput.prefill_tokens as f64 * 64.0;
    let mut rep = match Json::obj(vec![
        ("seed", Json::from(seed)),
        ("ttft_p50_ms", Json::from(r.report.ttft.percentile_ms(50.0))),
        ("ttft_p99_ms", Json::from(r.report.ttft.percentile_ms(99.0))),
        ("ttft_mean_ms", Json::from(r.report.ttft.mean_ms())),
        ("decode_tps", Json::from(r.report.throughput.decode_tps())),
        ("imbalance", Json::from(r.decode_pool.imbalance())),
        ("kv_bytes", Json::from(kv_bytes)),
        ("completed", Json::from(r.completed)),
        ("offered", Json::from(r.offered)),
        ("rejected", Json::from(r.report.rejected)),
        ("ttft_stages", r.ttft_stages),
    ]) {
        Json::Obj(m) => m,
        _ => unreachable!(),
    };
    // Classed points carry per-class columns on top of the standard set
    // (extra keys, so pre-SLO documents still validate).
    if p.class_mix.is_some() {
        for c in SloClass::ALL {
            rep.insert(
                format!("ttft_p99_{}_ms", c.name()),
                Json::from(r.ttft_by_class[c.rank()].percentile_ms(99.0)),
            );
            rep.insert(
                format!("rejected_{}", c.name()),
                Json::from(r.rejected_by_class[c.rank()]),
            );
        }
    }
    // Deadlined points score completion deadlines on both arms of a
    // rescue A/B; rescue points additionally carry the decision counters.
    if grid.class_deadline_ms.is_some() {
        let g = &r.decode_pool.rescue;
        rep.insert("deadline_met".into(), Json::from(g.deadline_met));
        rep.insert("deadline_missed".into(), Json::from(g.deadline_violated));
    }
    if p.rescue {
        let g = &r.decode_pool.rescue;
        rep.insert("rescue_preempted".into(), Json::from(g.preempted));
        rep.insert("rescue_migrated".into(), Json::from(g.migrated));
        rep.insert("rescue_deadline_met".into(), Json::from(g.rescue_deadline_met));
    }
    Ok(Json::Obj(rep))
}

/// One live replica: an in-process [`TestServer`] over mock engines,
/// driven by the loadgen's open-loop schedule (same arrival models and
/// seeds as the DES axis values).
fn run_live_replica(p: &PointParams, grid: &SweepGrid, live: &LiveOpts, seed: u64) -> Result<Json> {
    let mut cfg = RealClusterConfig {
        engine: EngineSpec::Mock(MockEngineConfig::default()),
        ..Default::default()
    };
    cfg.seed = seed;
    cfg.n_decode = p.shards.unwrap_or(2);
    cfg.decode_batch = 8;
    cfg.decode_policy = parse_policy(&p.policy)?.policy();
    cfg.kv_budget = p.kv_budget;
    if let Some(c) = &p.codec {
        cfg.kv_wire = KvCodec::parse(c).ok_or_else(|| anyhow!("unknown kv codec '{c}'"))?;
    }
    if !live.remote_decode.is_empty() {
        cfg.remote_decode = live.remote_decode.clone();
        cfg.n_decode = 0;
        // Externally-started shards must outlive every replica.
        cfg.stop_shards_on_drain = false;
    }
    if p.sched == "immediate" {
        cfg.mode = RealSchedMode::Immediate(ImmediatePolicy::LeastOutstanding);
    } else if p.window > 0.0 {
        if let RealSchedMode::Staggered(sc) = &mut cfg.mode {
            sc.interval.t_default = p.window;
            sc.interval.adaptive = false;
        }
    }
    if p.rescue {
        cfg.rescue = RescueConfig::on();
    }
    let server = TestServer::start(cfg);
    let model = loadgen::ArrivalModel::parse(&p.arrival)
        .with_context(|| "live mode supports the loadgen arrival models only")?;
    let schedule = loadgen::build_schedule(
        model,
        p.qps,
        grid.duration,
        seed,
        live.prompt_tokens,
        live.max_new,
        p.mix()?,
        grid.class_deadline_ms,
    );
    let offered = schedule.len();
    let report = loadgen::run_schedule(&server.addr, schedule, live.conns)?;
    let pool = loadgen::fetch_stats(&server.addr).unwrap_or(Json::Null);
    server.shutdown()?;
    let imbalance = pool.f64_at(&["imbalance"]).unwrap_or(1.0);
    let kv_bytes = pool.f64_at(&["kv_wire", "wire_bytes"]).unwrap_or(0.0);
    let mut rep = match Json::obj(vec![
        ("seed", Json::from(seed)),
        ("ttft_p50_ms", Json::from(report.ttft.percentile_ms(50.0))),
        ("ttft_p99_ms", Json::from(report.ttft.percentile_ms(99.0))),
        ("ttft_mean_ms", Json::from(report.ttft.mean_ms())),
        ("decode_tps", Json::from(report.tokens as f64 / report.elapsed_s.max(1e-9))),
        ("imbalance", Json::from(imbalance)),
        ("kv_bytes", Json::from(kv_bytes)),
        ("completed", Json::from(report.completed)),
        ("offered", Json::from(offered)),
        ("rejected", Json::from(report.busy)),
        (
            "ttft_stages",
            pool.get("ttft_stages").cloned().unwrap_or(Json::Null),
        ),
    ]) {
        Json::Obj(m) => m,
        _ => unreachable!(),
    };
    if p.class_mix.is_some() {
        for c in SloClass::ALL {
            rep.insert(
                format!("ttft_p99_{}_ms", c.name()),
                Json::from(report.ttft_by_class[c.rank()].percentile_ms(99.0)),
            );
            rep.insert(
                format!("rejected_{}", c.name()),
                Json::from(report.busy_by_class[c.rank()]),
            );
            // What the server's flow controller says it shed, per class
            // (distinct from client-observed BUSY, which also counts
            // mid-stream rejections).
            if let Some(v) = pool.f64_at(&["rejected_shed", c.name()]) {
                rep.insert(format!("rejected_shed_{}", c.name()), Json::from(v));
            }
        }
    }
    // Client-side deadline verdicts (scored from the scheduled arrival)
    // plus the server's rescue decision counters, mirroring the DES
    // columns so live points pair up the same way.
    if grid.class_deadline_ms.is_some() {
        rep.insert(
            "deadline_met".into(),
            Json::from(report.deadline_met_by_class.iter().sum::<u64>()),
        );
        rep.insert(
            "deadline_missed".into(),
            Json::from(report.deadline_missed_by_class.iter().sum::<u64>()),
        );
    }
    if p.rescue {
        rep.insert(
            "rescue_preempted".into(),
            Json::from(pool.f64_at(&["rescue", "preempted"]).unwrap_or(0.0)),
        );
        rep.insert(
            "rescue_migrated".into(),
            Json::from(pool.f64_at(&["rescue", "migrated"]).unwrap_or(0.0)),
        );
        rep.insert(
            "rescue_deadline_met".into(),
            Json::from(pool.f64_at(&["rescue", "rescue_deadline_met"]).unwrap_or(0.0)),
        );
    }
    Ok(Json::Obj(rep))
}

/// mean/std/min/max over the replicas for each summary metric. Std is the
/// sample (n−1) deviation — the noise estimate `--compare` thresholds on.
fn summarize(replicas: &[Json]) -> Json {
    let mut pairs = Vec::new();
    for &m in SUMMARY_METRICS {
        let xs: Vec<f64> = replicas.iter().filter_map(|r| r.f64_at(&[m])).collect();
        let (min, max) = xs.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &x| {
            (lo.min(x), hi.max(x))
        });
        pairs.push((
            m,
            Json::obj(vec![
                ("mean", Json::from(stats::mean(&xs))),
                ("std", Json::from(stats::sample_stddev(&xs))),
                ("min", Json::from(if xs.is_empty() { 0.0 } else { min })),
                ("max", Json::from(if xs.is_empty() { 0.0 } else { max })),
            ]),
        ));
    }
    Json::obj(pairs)
}

/// The M/M/1 sanity column for Poisson DES points (see module docs).
fn mm1_column(qps: f64) -> Json {
    let cost = PrefillCostModel::default();
    let topo = SimTopology::paper_3p1d(3072);
    let mean_input = LengthDist::paper_short().empirical_mean(9, 20_000);
    // Full-chunk pass on every DP unit; prompt tokens see on average half
    // the prompt as attention context.
    let full = DpPassLoad {
        tokens: topo.c_chunk,
        mean_ctx: mean_input / 2.0,
    };
    let loads = vec![full; topo.dp_prefill as usize];
    let t_pass = cost.pass_time(&loads);
    let mu_tokens = topo.n_prefill as f64 * topo.dp_prefill as f64 * topo.c_chunk as f64 / t_pass;
    let mu_qps = mu_tokens / mean_input;
    let rho = qps / mu_qps;
    let predicted = if rho < 1.0 {
        Json::from((1.0 / (mu_qps - qps) + t_pass + 0.002) * 1e3)
    } else {
        // Past saturation the M/M/1 sojourn diverges; the DES shows flow
        // control instead. Null marks "no finite prediction".
        Json::Null
    };
    Json::obj(vec![
        ("lambda_qps", Json::from(qps)),
        ("mu_qps", Json::from(mu_qps)),
        ("rho", Json::from(rho)),
        ("predicted_ttft_ms", predicted),
    ])
}

/// Run the full grid and assemble the versioned document. Pure virtual
/// time on the DES path: same grid + same seed ⇒ byte-identical output.
pub fn run_sweep(grid: &SweepGrid, modes: &SweepModes) -> Result<Json> {
    let mut points = Vec::new();
    if modes.des {
        for p in expand(grid, "des")? {
            log::info!(
                "sweep des point: {}/{}/{} qps={} window={} kv={}",
                p.sched,
                p.arrival,
                p.policy,
                p.qps,
                p.window,
                p.kv_budget
            );
            let mut reps = Vec::new();
            for r in 0..grid.replicas {
                reps.push(run_des_replica(&p, grid, grid.seed + r as u64)?);
            }
            let mm1 = if p.arrival == "poisson" {
                mm1_column(p.qps)
            } else {
                Json::Null
            };
            let summary = summarize(&reps);
            points.push(Json::obj(vec![
                ("params", p.to_json()),
                ("replicas", Json::Arr(reps)),
                ("summary", summary),
                ("mm1", mm1),
            ]));
        }
    }
    if let Some(live) = &modes.live {
        for p in expand(grid, "live")? {
            log::info!(
                "sweep live point: {}/{}/{} qps={} codec={:?} shards={:?}",
                p.sched,
                p.arrival,
                p.policy,
                p.qps,
                p.codec,
                p.shards
            );
            let mut reps = Vec::new();
            for r in 0..grid.replicas {
                reps.push(run_live_replica(&p, grid, live, grid.seed + r as u64)?);
            }
            let summary = summarize(&reps);
            points.push(Json::obj(vec![
                ("params", p.to_json()),
                ("replicas", Json::Arr(reps)),
                ("summary", summary),
                ("mm1", Json::Null),
            ]));
        }
    }
    Ok(Json::obj(vec![
        ("schema", Json::from(SCHEMA_NAME)),
        ("schema_version", Json::from(SCHEMA_VERSION)),
        ("bench_id", Json::from(modes.bench_id.as_str())),
        ("grid", grid.to_json()),
        ("points", Json::Arr(points)),
    ]))
}

/// Structural validation of a sweep document (the `--validate` and
/// `--compare` entry precondition).
pub fn validate(doc: &Json) -> Result<()> {
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'schema'"))?;
    if schema != SCHEMA_NAME {
        return Err(anyhow!("schema '{schema}' != '{SCHEMA_NAME}'"));
    }
    let ver = doc
        .f64_at(&["schema_version"])
        .ok_or_else(|| anyhow!("missing 'schema_version'"))? as u64;
    if ver != SCHEMA_VERSION {
        return Err(anyhow!("schema_version {ver} unsupported (want {SCHEMA_VERSION})"));
    }
    doc.get("bench_id")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'bench_id'"))?;
    let replicas = doc
        .f64_at(&["grid", "replicas"])
        .ok_or_else(|| anyhow!("missing 'grid.replicas'"))? as usize;
    let points = doc
        .get("points")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'points' array"))?;
    if points.is_empty() {
        return Err(anyhow!("'points' is empty"));
    }
    for (i, pt) in points.iter().enumerate() {
        let params = pt
            .get("params")
            .ok_or_else(|| anyhow!("point {i}: missing params"))?;
        for key in ["mode", "sched", "arrival", "policy"] {
            params
                .get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("point {i}: missing params.{key}"))?;
        }
        for key in ["qps", "stagger_window_s", "kv_budget_tokens"] {
            params
                .f64_at(&[key])
                .ok_or_else(|| anyhow!("point {i}: missing params.{key}"))?;
        }
        let reps = pt
            .get("replicas")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("point {i}: missing replicas"))?;
        if reps.len() != replicas {
            return Err(anyhow!(
                "point {i}: {} replicas, grid declares {replicas}",
                reps.len()
            ));
        }
        for (r, rep) in reps.iter().enumerate() {
            for &f in REPLICA_FIELDS {
                rep.f64_at(&[f])
                    .ok_or_else(|| anyhow!("point {i} replica {r}: missing {f}"))?;
            }
        }
        for &m in SUMMARY_METRICS {
            for f in ["mean", "std", "min", "max"] {
                pt.f64_at(&["summary", m, f])
                    .ok_or_else(|| anyhow!("point {i}: missing summary.{m}.{f}"))?;
            }
        }
        match pt.get("mm1") {
            Some(Json::Null) => {}
            Some(mm1) => {
                for key in ["lambda_qps", "mu_qps", "rho"] {
                    mm1.f64_at(&[key])
                        .ok_or_else(|| anyhow!("point {i}: missing mm1.{key}"))?;
                }
                // predicted_ttft_ms may legitimately be null (ρ ≥ 1) but
                // the key must exist.
                mm1.get("predicted_ttft_ms")
                    .ok_or_else(|| anyhow!("point {i}: missing mm1.predicted_ttft_ms"))?;
            }
            None => return Err(anyhow!("point {i}: missing mm1 (use null)")),
        }
    }
    Ok(())
}

/// Outcome of comparing two documents.
#[derive(Debug, Default)]
pub struct CompareReport {
    /// Grid points present in both documents.
    pub compared: usize,
    /// Metric changes in the "worse" direction beyond threshold.
    pub regressions: Vec<String>,
    /// Metric changes in the "better" direction beyond threshold.
    pub improvements: Vec<String>,
    /// Points only in the old document (removed).
    pub only_old: usize,
    /// Points only in the new document (added).
    pub only_new: usize,
}

fn point_label(pt: &Json) -> String {
    let p = pt.get("params");
    let s = |k: &str| {
        p.and_then(|p| p.get(k))
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string()
    };
    let n = |k: &str| p.and_then(|p| p.f64_at(&[k])).unwrap_or(0.0);
    format!(
        "[{}/{}/{}/{} qps={} w={} kv={}]",
        s("mode"),
        s("sched"),
        s("arrival"),
        s("policy"),
        n("qps"),
        n("stagger_window_s"),
        n("kv_budget_tokens")
    )
}

/// Compare `new` against the `old` baseline. A metric regresses when its
/// replica-mean moves in the worse direction by more than
/// `max(rel_threshold × |old mean|, sigma × √(σ_old²/n_old + σ_new²/n_new))`
/// — the second term is the combined standard error of the two means, so
/// the gate is noise-aware by construction.
pub fn compare(old: &Json, new: &Json, rel_threshold: f64, sigma: f64) -> Result<CompareReport> {
    validate(old).context("old document")?;
    validate(new).context("new document")?;
    let n_old = old.f64_at(&["grid", "replicas"]).unwrap_or(1.0).max(1.0);
    let n_new = new.f64_at(&["grid", "replicas"]).unwrap_or(1.0).max(1.0);
    let index = |doc: &Json| -> BTreeMap<String, Json> {
        doc.get("points")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|pt| pt.get("params").map(|p| (p.dump(), pt.clone())))
            .collect()
    };
    let old_pts = index(old);
    let new_pts = index(new);
    let mut rep = CompareReport::default();
    for (key, op) in &old_pts {
        let Some(np) = new_pts.get(key) else {
            rep.only_old += 1;
            continue;
        };
        rep.compared += 1;
        let label = point_label(op);
        for &(metric, higher_is_worse) in COMPARE_METRICS {
            let om = op
                .f64_at(&["summary", metric, "mean"])
                .ok_or_else(|| anyhow!("old {label}: missing summary.{metric}.mean"))?;
            let os = op.f64_at(&["summary", metric, "std"]).unwrap_or(0.0);
            let nm = np
                .f64_at(&["summary", metric, "mean"])
                .ok_or_else(|| anyhow!("new {label}: missing summary.{metric}.mean"))?;
            let ns = np.f64_at(&["summary", metric, "std"]).unwrap_or(0.0);
            let stderr = (os * os / n_old + ns * ns / n_new).sqrt();
            let threshold = (rel_threshold * om.abs()).max(sigma * stderr);
            let delta = if higher_is_worse { nm - om } else { om - nm };
            let pct = (nm - om) / om.abs().max(1e-12) * 100.0;
            let line = format!("{label} {metric}: {om:.2} -> {nm:.2} ({pct:+.1}%)");
            if delta > threshold {
                rep.regressions.push(line);
            } else if -delta > threshold {
                rep.improvements.push(line);
            }
        }
    }
    rep.only_new = new_pts.keys().filter(|k| !old_pts.contains_key(*k)).count();
    Ok(rep)
}

fn split_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(|x| x.trim().to_string())
        .filter(|x| !x.is_empty())
        .collect()
}

fn parse_f64_list(s: &str) -> Result<Vec<f64>> {
    split_list(s)
        .into_iter()
        .map(|x| x.parse::<f64>().map_err(|_| anyhow!("bad number '{x}'")))
        .collect()
}

fn parse_u64_list(s: &str) -> Result<Vec<u64>> {
    split_list(s)
        .into_iter()
        .map(|x| x.parse::<u64>().map_err(|_| anyhow!("bad integer '{x}'")))
        .collect()
}

fn load_doc(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    crate::json::parse(&text).map_err(|e| anyhow!("{path}: bad JSON: {e:?}"))
}

/// `sbs sweep` entrypoint.
pub fn cli_sweep(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "sbs sweep",
        "replicated parameter-sweep experiments emitting BENCH_*.json",
    )
    .opt(
        "sched",
        "comma list: staggered,immediate",
        Some("staggered,immediate"),
    )
    .opt(
        "arrival",
        "comma list: poisson,bursty,heavy-tail,uniform",
        Some("poisson,bursty"),
    )
    .opt(
        "decode-policy",
        "comma list: load-aware,round-robin,random",
        Some("load-aware"),
    )
    .opt("qps", "comma list of offered rates", Some("100"))
    .opt(
        "window",
        "comma list of static stagger windows, seconds (0 = adaptive)",
        Some("0"),
    )
    .opt(
        "kv-budget",
        "comma list of per-DP decode KV budgets",
        Some(config::LIVE_KV_BUDGET_TOKENS_STR),
    )
    .opt(
        "kv-wire",
        "comma list of live-mode KV codecs: raw,fp16,lz",
        Some("raw"),
    )
    .opt(
        "shards",
        "comma list of live-mode local decode pool sizes (DP units)",
        Some("2"),
    )
    .opt(
        "class-mix",
        "semicolon list of SLO class mixes (none = class-less), e.g. \
         'none;interactive:0.2,standard:0.5,batch:0.3'",
        Some("none;interactive:0.2,standard:0.5,batch:0.3"),
    )
    .opt(
        "rescue",
        "comma list: off,on (SLO-violation decode rescue axis)",
        Some("off"),
    )
    .opt(
        "class-deadline-ms",
        "per-class completion deadlines in ms (class-mix grammar), e.g. \
         'interactive:800'; empty = deadline-free traffic",
        Some(""),
    )
    .opt("replicas", "seeded runs per grid point", Some("3"))
    .opt("seed", "base seed (replica r runs at seed+r)", Some("1"))
    .opt(
        "duration",
        "offered-load horizon per replica, seconds",
        Some("45"),
    )
    .opt("warmup", "metrics warmup cut, seconds (DES)", Some("10"))
    .opt(
        "bench-id",
        "identifier stamped into the document",
        Some("BENCH_9"),
    )
    .opt("out", "write the document here (default: stdout)", None)
    .opt(
        "rel-threshold",
        "compare: relative regression floor",
        Some("0.25"),
    )
    .opt(
        "sigma",
        "compare: multiplier on the replica-noise stderr",
        Some("3"),
    )
    .opt("live-conns", "live mode: loadgen connections", Some("8"))
    .opt("live-prompt-tokens", "live mode: prompt length", Some("48"))
    .opt("live-max-new", "live mode: generation budget", Some("16"))
    .opt(
        "live-remote-decode",
        "live mode: pre-started decode shard addrs (addr,addr)",
        None,
    )
    .flag(
        "live",
        "also run each point on an in-process live mock cluster",
    )
    .flag("no-des", "skip the DES pass (with --live: live only)")
    .flag(
        "compare",
        "compare two documents: sbs sweep --compare old.json new.json",
    )
    .flag(
        "validate",
        "validate a document: sbs sweep --validate doc.json",
    );
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;

    if args.flag("validate") {
        let path = args
            .positional
            .first()
            .ok_or_else(|| anyhow!("--validate needs a document path"))?;
        let doc = load_doc(path)?;
        validate(&doc).with_context(|| format!("{path}: invalid"))?;
        let n = doc.get("points").and_then(Json::as_arr).map_or(0, <[Json]>::len);
        println!("{path}: valid {SCHEMA_NAME} v{SCHEMA_VERSION}, {n} grid points");
        return Ok(());
    }

    if args.flag("compare") {
        let (old_path, new_path) = match args.positional.as_slice() {
            [a, b] => (a, b),
            _ => return Err(anyhow!("--compare needs exactly two document paths")),
        };
        let rel: f64 = args.parse_or("rel-threshold", 0.25).map_err(|e| anyhow!("{e}"))?;
        let sigma: f64 = args.parse_or("sigma", 3.0).map_err(|e| anyhow!("{e}"))?;
        let rep = compare(&load_doc(old_path)?, &load_doc(new_path)?, rel, sigma)?;
        println!(
            "compared {} grid points ({} added, {} removed)",
            rep.compared, rep.only_new, rep.only_old
        );
        for line in &rep.improvements {
            println!("improved  {line}");
        }
        for line in &rep.regressions {
            println!("REGRESSED {line}");
        }
        if rep.compared == 0 {
            return Err(anyhow!("no overlapping grid points — nothing was compared"));
        }
        if !rep.regressions.is_empty() {
            return Err(anyhow!(
                "{} metric regression(s) beyond thresholds (rel {rel}, sigma {sigma})",
                rep.regressions.len()
            ));
        }
        println!("no regressions beyond thresholds (rel {rel}, sigma {sigma})");
        return Ok(());
    }

    let grid = SweepGrid {
        scheds: split_list(&args.str_or("sched", "staggered,immediate")),
        arrivals: split_list(&args.str_or("arrival", "poisson,bursty")),
        policies: split_list(&args.str_or("decode-policy", "load-aware")),
        qps: parse_f64_list(&args.str_or("qps", "100"))?,
        windows: parse_f64_list(&args.str_or("window", "0"))?,
        kv_budgets: parse_u64_list(&args.str_or("kv-budget", config::LIVE_KV_BUDGET_TOKENS_STR))?,
        codecs: split_list(&args.str_or("kv-wire", "raw")),
        shards: parse_u64_list(&args.str_or("shards", "2"))?
            .into_iter()
            .map(|s| u32::try_from(s).map_err(|_| anyhow!("shard count {s} too large")))
            .collect::<Result<_>>()?,
        class_mixes: {
            let mixes: Vec<String> = args
                .str_or("class-mix", "none;interactive:0.2,standard:0.5,batch:0.3")
                .split(';')
                .map(|m| m.trim().to_string())
                .filter(|m| !m.is_empty())
                .collect();
            if mixes.is_empty() {
                vec!["none".into()]
            } else {
                mixes
            }
        },
        rescues: split_list(&args.str_or("rescue", "off")),
        class_deadline_ms: {
            let s = args.str_or("class-deadline-ms", "");
            if s.is_empty() {
                None
            } else {
                Some(parse_class_mix(&s).map_err(|e| anyhow!(e))?)
            }
        },
        replicas: args.parse_or("replicas", 3u32).map_err(|e| anyhow!("{e}"))?,
        seed: args.parse_or("seed", 1u64).map_err(|e| anyhow!("{e}"))?,
        duration: args.parse_or("duration", 45.0).map_err(|e| anyhow!("{e}"))?,
        warmup: args.parse_or("warmup", 10.0).map_err(|e| anyhow!("{e}"))?,
    };
    if grid.replicas == 0 {
        return Err(anyhow!("--replicas must be >= 1"));
    }
    let live = if args.flag("live") {
        Some(LiveOpts {
            remote_decode: args
                .value("live-remote-decode")
                .map(split_list)
                .unwrap_or_default(),
            prompt_tokens: args
                .parse_or("live-prompt-tokens", 48u32)
                .map_err(|e| anyhow!("{e}"))?,
            max_new: args.parse_or("live-max-new", 16u32).map_err(|e| anyhow!("{e}"))?,
            conns: args.parse_or("live-conns", 8usize).map_err(|e| anyhow!("{e}"))?,
        })
    } else {
        None
    };
    let modes = SweepModes {
        bench_id: args.str_or("bench-id", "BENCH_9"),
        des: !args.flag("no-des"),
        live,
    };
    if !modes.des && modes.live.is_none() {
        return Err(anyhow!("--no-des without --live leaves nothing to run"));
    }
    let doc = run_sweep(&grid, &modes)?;
    match args.value("out") {
        Some(path) => {
            std::fs::write(path, doc.dump() + "\n")
                .with_context(|| format!("writing {path}"))?;
            let n = doc.get("points").and_then(Json::as_arr).map_or(0, <[Json]>::len);
            eprintln!("wrote {path}: {n} grid points x {} replicas", grid.replicas);
        }
        None => println!("{}", doc.dump()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> SweepGrid {
        SweepGrid {
            scheds: vec!["staggered".into(), "immediate".into()],
            arrivals: vec!["poisson".into()],
            policies: vec!["load-aware".into()],
            qps: vec![10.0],
            windows: vec![0.0, 0.5],
            kv_budgets: vec![150_000],
            codecs: vec!["raw".into(), "lz".into()],
            shards: vec![2, 16],
            class_mixes: vec!["none".into()],
            rescues: vec!["off".into()],
            class_deadline_ms: None,
            replicas: 2,
            seed: 5,
            duration: 4.0,
            warmup: 1.0,
        }
    }

    #[test]
    fn expand_collapses_window_axis_for_immediate() {
        let pts = expand(&tiny_grid(), "des").unwrap();
        // staggered × 2 windows + immediate × 1 (collapsed) = 3 points,
        // and no DES point carries the live-only axes.
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.codec.is_none() && p.shards.is_none()));
        let imm: Vec<_> = pts.iter().filter(|p| p.sched == "immediate").collect();
        assert_eq!(imm.len(), 1);
        assert_eq!(imm[0].window, 0.0);
    }

    #[test]
    fn expand_fans_codecs_and_shards_out_in_live_mode_only() {
        let pts = expand(&tiny_grid(), "live").unwrap();
        // 3 scheduler/window points × 2 codecs × 2 shard counts.
        assert_eq!(pts.len(), 12);
        assert!(pts.iter().all(|p| p.codec.is_some() && p.shards.is_some()));
        for want in [2u32, 16] {
            assert!(pts.iter().any(|p| p.shards == Some(want)));
        }
    }

    #[test]
    fn class_mix_axis_fans_out_and_stays_off_legacy_params() {
        let mut g = tiny_grid();
        g.class_mixes = vec!["none".into(), "interactive:0.2,standard:0.5,batch:0.3".into()];
        let pts = expand(&g, "des").unwrap();
        // Every scheduler/window point doubles: one class-less, one classed.
        assert_eq!(pts.len(), 6);
        let classless: Vec<_> = pts.iter().filter(|p| p.class_mix.is_none()).collect();
        assert_eq!(classless.len(), 3);
        // Class-less params must index identically to a pre-SLO document:
        // no class_mix key at all.
        assert!(classless.iter().all(|p| p.to_json().get("class_mix").is_none()));
        let classed: Vec<_> = pts.iter().filter(|p| p.class_mix.is_some()).collect();
        assert_eq!(
            classed[0].to_json().get("class_mix").and_then(Json::as_str),
            Some("interactive:0.2,standard:0.5,batch:0.3")
        );
        // Bad mixes fail at expansion, not hours into the sweep.
        g.class_mixes = vec!["premium:1".into()];
        assert!(expand(&g, "des").is_err());
    }

    #[test]
    fn rescue_axis_fans_out_and_off_keeps_legacy_params() {
        let mut g = tiny_grid();
        g.rescues = vec!["off".into(), "on".into()];
        let pts = expand(&g, "des").unwrap();
        // Every scheduler/window point doubles: one off-arm, one on-arm.
        assert_eq!(pts.len(), 6);
        let off: Vec<_> = pts.iter().filter(|p| !p.rescue).collect();
        assert_eq!(off.len(), 3);
        // Off-arm params must index identically to a pre-rescue document:
        // no rescue key at all.
        assert!(off.iter().all(|p| p.to_json().get("rescue").is_none()));
        assert!(pts
            .iter()
            .filter(|p| p.rescue)
            .all(|p| p.to_json().get("rescue").and_then(Json::as_str) == Some("on")));
        // Bad axis values fail at expansion.
        g.rescues = vec!["maybe".into()];
        assert!(expand(&g, "des").is_err());
    }

    #[test]
    fn deadline_aware_is_a_valid_policy_axis() {
        let mut g = tiny_grid();
        g.policies = vec!["deadline-aware".into()];
        assert!(expand(&g, "des").is_ok());
    }

    #[test]
    fn expand_rejects_bad_axis_values() {
        let mut g = tiny_grid();
        g.arrivals = vec!["tuesday".into()];
        assert!(expand(&g, "des").is_err());
        let mut g = tiny_grid();
        g.policies = vec!["psychic".into()];
        assert!(expand(&g, "des").is_err());
        let mut g = tiny_grid();
        g.scheds = vec!["eager".into()];
        assert!(expand(&g, "des").is_err());
    }

    #[test]
    fn list_parsing() {
        assert_eq!(split_list("a, b,,c "), vec!["a", "b", "c"]);
        assert_eq!(parse_f64_list("1,2.5").unwrap(), vec![1.0, 2.5]);
        assert_eq!(parse_u64_list("3,4").unwrap(), vec![3, 4]);
        assert!(parse_f64_list("1,x").is_err());
        assert!(parse_u64_list("1.5").is_err());
    }

    #[test]
    fn mm1_finite_below_saturation_divergent_above() {
        let low = mm1_column(50.0);
        let rho = low.f64_at(&["rho"]).unwrap();
        assert!(rho > 0.0 && rho < 1.0, "rho={rho}");
        let p = low.f64_at(&["predicted_ttft_ms"]).unwrap();
        // Sub-second but slower than a bare chunk pass: sane TTFT scale.
        assert!(p > 100.0 && p < 2_000.0, "predicted={p}");
        // Heavier load must predict strictly worse TTFT.
        let high = mm1_column(150.0);
        if let Some(p_hi) = high.f64_at(&["predicted_ttft_ms"]) {
            assert!(p_hi > p);
        }
        // Far past saturation: no finite prediction.
        let over = mm1_column(10_000.0);
        assert!(over.f64_at(&["rho"]).unwrap() > 1.0);
        assert_eq!(over.path(&["predicted_ttft_ms"]), Some(&Json::Null));
    }

    #[test]
    fn summarize_uses_sample_std() {
        let reps = vec![
            crate::json::parse(r#"{"ttft_p99_ms": 1.0}"#).unwrap(),
            crate::json::parse(r#"{"ttft_p99_ms": 3.0}"#).unwrap(),
        ];
        let s = summarize(&reps);
        assert_eq!(s.f64_at(&["ttft_p99_ms", "mean"]), Some(2.0));
        // Sample (n−1) std of {1,3} is √2.
        let std = s.f64_at(&["ttft_p99_ms", "std"]).unwrap();
        assert!((std - 2.0_f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.f64_at(&["ttft_p99_ms", "min"]), Some(1.0));
        assert_eq!(s.f64_at(&["ttft_p99_ms", "max"]), Some(3.0));
        // Metrics absent from every replica summarize to zeros, not NaN.
        assert_eq!(s.f64_at(&["decode_tps", "mean"]), Some(0.0));
    }
}
