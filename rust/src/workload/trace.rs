//! JSONL trace record/replay: one request per line, so production traces
//! (or generated workloads) can be captured once and replayed bit-exactly
//! across scheduler variants.

use crate::json::{parse, Json};
use crate::scheduler::{Request, SloClass};
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Serialize one request to a JSON object.
fn to_json(r: &Request) -> Json {
    let mut fields = vec![
        ("id", Json::from(r.id)),
        ("input_tokens", Json::from(r.input_tokens)),
        ("output_tokens", Json::from(r.output_tokens)),
        ("arrival", Json::from(r.arrival)),
    ];
    if let Some(g) = r.prefix_group {
        fields.push(("prefix_group", Json::from(g)));
        fields.push(("prefix_len", Json::from(r.prefix_len)));
    }
    // Class-less standard requests stay byte-identical to pre-SLO traces.
    if r.class != SloClass::Standard {
        fields.push(("class", Json::Str(r.class.name().to_string())));
    }
    if let Some(d) = r.deadline {
        fields.push(("deadline", Json::from(d)));
    }
    Json::obj(fields)
}

/// Parse one request from a JSON object.
fn from_json(j: &Json) -> Result<Request> {
    let get_u32 = |k: &str| -> Result<u32> {
        j.get(k)
            .and_then(Json::as_usize)
            .map(|x| x as u32)
            .ok_or_else(|| anyhow!("missing/invalid field '{k}'"))
    };
    let id = j
        .get("id")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing 'id'"))? as u64;
    let arrival = j
        .get("arrival")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing 'arrival'"))?;
    let mut r = Request::new(id, get_u32("input_tokens")?, get_u32("output_tokens")?, arrival);
    if let Some(g) = j.get("prefix_group").and_then(Json::as_f64) {
        let plen = get_u32("prefix_len")?.min(r.input_tokens);
        r = r.with_prefix(g as u64, plen);
    }
    if let Some(c) = j.get("class").and_then(Json::as_str) {
        let c = SloClass::parse(c).ok_or_else(|| anyhow!("unknown SLO class '{c}'"))?;
        r = r.with_class(c);
    }
    if let Some(d) = j.get("deadline").and_then(Json::as_f64) {
        r = r.with_deadline(d);
    }
    Ok(r)
}

/// Write a request trace as JSONL.
pub fn write_trace(path: &Path, requests: &[Request]) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for r in requests {
        writeln!(w, "{}", to_json(r).dump())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a JSONL request trace.
pub fn read_trace(path: &Path) -> Result<Vec<Request>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening trace file {}", path.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = parse(&line).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        out.push(from_json(&j).with_context(|| format!("line {}", lineno + 1))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    #[test]
    fn roundtrip_trace() {
        let dir = std::env::temp_dir().join("sbs_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let mut spec = WorkloadSpec::paper_short(30.0, 5.0, 11);
        spec.prefix = Some(crate::workload::PrefixSpec {
            groups: 4,
            zipf_s: 1.0,
            prefix_len: crate::workload::LengthDist::Fixed(64),
            participation: 0.5,
        });
        let reqs = spec.generate();
        write_trace(&path, &reqs).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(reqs.len(), back.len());
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.input_tokens, b.input_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert_eq!(a.prefix_group, b.prefix_group);
            assert_eq!(a.prefix_len, b.prefix_len);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn classed_requests_round_trip() {
        let dir = std::env::temp_dir().join("sbs_trace_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("classed.jsonl");
        let reqs = vec![
            Request::new(0, 100, 10, 0.0).with_class(SloClass::Interactive),
            Request::new(1, 100, 10, 0.1).with_class(SloClass::Batch).with_deadline(2.5),
            Request::new(2, 100, 10, 0.2), // class-less
        ];
        write_trace(&path, &reqs).unwrap();
        let back = read_trace(&path).unwrap();
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.deadline, b.deadline);
        }
        // The class-less line carries neither key — legacy consumers see
        // the exact pre-SLO schema.
        let raw = std::fs::read_to_string(&path).unwrap();
        let last = raw.lines().nth(2).unwrap();
        assert!(!last.contains("class") && !last.contains("deadline"), "{last}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("sbs_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\": 1}\n").unwrap();
        assert!(read_trace(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
