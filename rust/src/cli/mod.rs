//! Command-line argument parsing substrate (the offline registry has no
//! `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with generated `--help` text.

use std::collections::BTreeMap;

/// Declarative description of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without the leading `--`.
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// Whether the option takes a value (`--key v`) or is a boolean flag.
    pub takes_value: bool,
    /// Default value rendered in help (informational only).
    pub default: Option<&'static str>,
}

/// Parsed arguments: flag set, key/value options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, bool>,
    values: BTreeMap<String, String>,
    /// Positional arguments, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// True when `--name` was present as a flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Raw string value of `--name`, if given.
    pub fn value(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// String value with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.value(name).unwrap_or(default).to_string()
    }

    /// Parse `--name` as `T`, falling back to `default` when absent.
    /// Returns an error string on malformed input (so callers can print
    /// usage instead of panicking).
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.value(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| format!("invalid value for --{name}: '{s}'")),
        }
    }
}

/// A subcommand parser: spec + collected args.
#[derive(Debug)]
pub struct Command {
    /// Binary or subcommand name for help output.
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

impl Command {
    /// New command description.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Add a value-taking option.
    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let lhs = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {lhs:<24} {}{def}\n", o.help));
        }
        s.push_str("  --help                   show this message\n");
        s
    }

    /// Parse a raw argv slice. Unknown `--options` are an error; `--help`
    /// yields `Err(help_text)` for the caller to print.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.help());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help()))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    out.values.insert(name, v);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    out.flags.insert(name, true);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .flag("verbose", "be chatty")
            .opt("qps", "target qps", Some("10"))
            .opt("out", "output path", None)
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = cmd()
            .parse(&sv(&["--verbose", "--qps", "25", "pos1", "--out=x.json"]))
            .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_or("qps", 0u32).unwrap(), 25);
        assert_eq!(a.value("out"), Some("x.json"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn default_when_absent() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.parse_or("qps", 7.5f64).unwrap(), 7.5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn help_requested() {
        let e = cmd().parse(&sv(&["--help"])).unwrap_err();
        assert!(e.contains("--qps"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&sv(&["--qps"])).is_err());
    }

    #[test]
    fn malformed_value_error() {
        let a = cmd().parse(&sv(&["--qps", "abc"])).unwrap();
        assert!(a.parse_or("qps", 0u32).is_err());
    }
}
