//! The real mini inference engine on top of [`crate::runtime`]: chunked
//! prefill, slot-based batched decode with a persistent KV cache, byte
//! tokenizer and sampling.
//!
//! One [`MiniEngine`] is one "instance" of the paper's resource plane in
//! real mode: its prefill is gated and non-preemptive (one chunk pass at
//! a time), its decode runs synchronized batch steps — the same structural
//! properties the DES models, with actual PJRT forward passes.

pub mod mock;
pub mod sampler;
pub mod tokenizer;

use crate::runtime::backend::Literal;
use crate::runtime::Runtime;
use crate::util::Rng;
use anyhow::{anyhow, bail, Result};
use sampler::Sampling;
use std::sync::Arc;

/// Result of a full chunked prefill of one prompt.
pub struct PrefillOutcome {
    /// First generated token (argmax over the final real position).
    pub first_token: i32,
    /// Prompt length in tokens (valid KV rows).
    pub len: usize,
    /// Final K caches `[L, S, H, Dh]` as host f32.
    pub k: Vec<f32>,
    /// Final V caches.
    pub v: Vec<f32>,
    /// Total PJRT execution time across chunks, seconds.
    pub exec_time: f64,
    /// Number of forward passes used.
    pub passes: u32,
}

/// One active decode slot.
#[derive(Debug, Clone)]
struct Slot {
    request_id: u64,
    len: i32,
    generated: u32,
    max_new: u32,
    last_token: i32,
}

/// A token emitted by one decode step.
#[derive(Debug, Clone)]
pub struct Emission {
    /// Request that produced the token.
    pub request_id: u64,
    /// The token id.
    pub token: i32,
    /// Whether the sequence finished (budget exhausted or EOS).
    pub done: bool,
}

/// Uniform interface over execution backends (real PJRT [`MiniEngine`]
/// or the dependency-free [`mock::MockEngine`]), so the threaded cluster
/// fabric in [`crate::cluster::workers`] is generic over how forward
/// passes actually run. Engines are constructed *inside* worker threads
/// (PJRT handles are not `Send`), so the trait itself needs no `Send`
/// bound — only the spec describing how to build one crosses threads.
pub trait EngineBackend {
    /// Full chunked prefill of one prompt.
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOutcome>;
    /// Number of free decode slots.
    fn free_slots(&self) -> usize;
    /// Number of active sequences.
    fn active(&self) -> usize;
    /// Admit a prefilled sequence into a free slot.
    fn admit(&mut self, pre: &PrefillOutcome, max_new: u32, request_id: u64) -> Result<usize>;
    /// One synchronized decode step over all active slots.
    fn step(&mut self) -> Result<(Vec<Emission>, f64)>;
    /// Drop every active sequence immediately, freeing all slots (no
    /// emissions for the dropped sequences will follow). Used when a new
    /// owner supersedes whoever admitted them — stale request ids must
    /// not keep generating, or they could collide with the new owner's.
    fn abort_all(&mut self);
    /// Release one active sequence mid-generation, freeing its slot (no
    /// further emissions for it). Returns the unconsumed token budget
    /// (`max_new − generated`) so a rescue extraction can re-admit the
    /// sequence elsewhere with exactly the work it had left; `None` if
    /// the request is not resident (already finished or never admitted).
    fn release(&mut self, request_id: u64) -> Option<u32>;
}

impl EngineBackend for MiniEngine {
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOutcome> {
        MiniEngine::prefill(self, prompt)
    }

    fn free_slots(&self) -> usize {
        MiniEngine::free_slots(self)
    }

    fn active(&self) -> usize {
        MiniEngine::active(self)
    }

    fn admit(&mut self, pre: &PrefillOutcome, max_new: u32, request_id: u64) -> Result<usize> {
        MiniEngine::admit(self, pre, max_new, request_id)
    }

    fn step(&mut self) -> Result<(Vec<Emission>, f64)> {
        MiniEngine::step(self)
    }

    fn abort_all(&mut self) {
        MiniEngine::abort_all(self)
    }

    fn release(&mut self, request_id: u64) -> Option<u32> {
        MiniEngine::release(self, request_id)
    }
}

/// Slot-based batched decoder + chunked prefill over the PJRT runtime.
pub struct MiniEngine {
    rt: Arc<Runtime>,
    batch: usize,
    // Host mirrors of the batched decode caches [L, B, S, H, Dh].
    kc: Vec<f32>,
    vc: Vec<f32>,
    // Perf: between decode steps the caches live as the previous step's
    // output literals; the f32 mirrors are refreshed lazily only when an
    // admission must splice in prompt KV (saves ~4 large memcpys/step).
    cache_lits: Option<(Literal, Literal)>,
    vecs_stale: bool,
    slots: Vec<Option<Slot>>,
    layers: usize,
    max_seq: usize,
    head_elems: usize, // H * Dh
    sampling: Sampling,
    rng: Rng,
}

impl MiniEngine {
    /// Build an engine with the given decode batch size (must be one of
    /// the compiled variants).
    pub fn new(rt: Arc<Runtime>, batch: u32, sampling: Sampling, seed: u64) -> Result<Self> {
        if !rt.decode_batches().contains(&batch) {
            bail!(
                "decode batch {batch} not among compiled variants {:?}",
                rt.decode_batches()
            );
        }
        let m = &rt.meta.model;
        let n = m.n_layers * batch as usize * m.max_seq * m.n_heads * m.d_head;
        Ok(MiniEngine {
            layers: m.n_layers,
            max_seq: m.max_seq,
            head_elems: m.n_heads * m.d_head,
            kc: vec![0.0; n],
            vc: vec![0.0; n],
            cache_lits: None,
            vecs_stale: false,
            slots: vec![None; batch as usize],
            batch: batch as usize,
            rt,
            sampling,
            rng: Rng::new(seed),
        })
    }

    /// Chunked prefill of `prompt` (any length < max_seq): runs compiled
    /// chunk passes (largest chunks first, padded final chunk). The
    /// returned logits correspond to the last *real* token because PAD
    /// positions sit strictly after it and attention is causal — but the
    /// AOT entry returns last-chunk-position logits, so the final chunk is
    /// sized to end exactly at the prompt's last token by choosing the
    /// smallest compiled chunk ≥ the remainder and masking: we instead
    /// re-run position accounting such that padded tail tokens never
    /// contribute (they are written to rows ≥ len and later overwritten by
    /// decode).
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillOutcome> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() >= self.max_seq {
            bail!("prompt length {} >= max_seq {}", prompt.len(), self.max_seq);
        }
        let chunks = self.rt.prefill_chunks();
        let min_chunk = *chunks.first().ok_or_else(|| anyhow!("no prefill variants"))? as usize;
        let max_chunk = *chunks.last().unwrap() as usize;
        let mut kc = self.rt.empty_prefill_cache();
        let mut vc = self.rt.empty_prefill_cache();
        let mut pos = 0usize;
        let mut exec_time = 0.0;
        let mut passes = 0u32;
        let mut last_logits: Vec<f32> = Vec::new();
        while pos < prompt.len() {
            let remaining = prompt.len() - pos;
            // Pick the chunk: full big chunks while they fit entirely,
            // otherwise the smallest compiled chunk covering the tail.
            let chunk = if remaining >= max_chunk {
                max_chunk
            } else {
                round_up(remaining, min_chunk).min(max_chunk)
            };
            if pos + chunk > self.max_seq {
                bail!("prompt + padding exceeds max_seq");
            }
            let real = remaining.min(chunk);
            let mut toks: Vec<i32> = Vec::with_capacity(chunk);
            toks.extend_from_slice(&prompt[pos..pos + real]);
            toks.resize(chunk, tokenizer::PAD);
            let step = self.rt.prefill_chunk(&toks, &kc, &vc, pos as i32)?;
            exec_time += step.exec_time;
            passes += 1;
            last_logits = step.logits_at(real - 1);
            kc = step.k_caches;
            vc = step.v_caches;
            pos += real;
        }
        let first_token = sampler::argmax(&last_logits);
        Ok(PrefillOutcome {
            first_token,
            len: prompt.len(),
            k: literal_to_vec(&kc)?,
            v: literal_to_vec(&vc)?,
            exec_time,
            passes,
        })
    }

    /// Number of free decode slots.
    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// Drop every active sequence, freeing all slots. The KV rows of the
    /// dropped sequences stay in the caches as dead weight until an
    /// admission overwrites them — causal masking keeps them invisible.
    pub fn abort_all(&mut self) {
        self.slots.iter_mut().for_each(|s| *s = None);
    }

    /// Release one sequence mid-generation (rescue extraction), freeing
    /// its slot and returning the unconsumed budget. Its KV rows stay as
    /// dead weight like [`MiniEngine::abort_all`]'s.
    pub fn release(&mut self, request_id: u64) -> Option<u32> {
        let slot = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|s| s.request_id == request_id))?;
        let remaining = self.slots[slot]
            .as_ref()
            .map(|s| s.max_new.saturating_sub(s.generated))?;
        self.slots[slot] = None;
        Some(remaining)
    }

    /// Number of active sequences.
    pub fn active(&self) -> usize {
        self.batch - self.free_slots()
    }

    /// Per-slot `(active, kv_tokens)` loads — the Algorithm 3 observable.
    pub fn slot_loads(&self) -> Vec<(u32, u64)> {
        self.slots
            .iter()
            .map(|s| match s {
                Some(s) => (1u32, s.len as u64),
                None => (0u32, 0u64),
            })
            .collect()
    }

    /// Admit a prefilled sequence into a free slot; returns the slot id.
    pub fn admit(&mut self, pre: &PrefillOutcome, max_new: u32, request_id: u64) -> Result<usize> {
        let slot = self
            .slots
            .iter()
            .position(Option::is_none)
            .ok_or_else(|| anyhow!("no free decode slot"))?;
        // Refresh host mirrors from the authoritative literals before
        // splicing in this sequence's KV rows.
        if self.vecs_stale {
            if let Some((kl, vl)) = &self.cache_lits {
                self.kc = literal_to_vec(kl)?;
                self.vc = literal_to_vec(vl)?;
            }
            self.vecs_stale = false;
        }
        self.cache_lits = None; // mirrors are about to change
        let budget = (self.max_seq - pre.len - 1) as u32;
        let max_new = max_new.min(budget).max(1);
        // Copy the prompt's KV rows into the slot region of the host
        // mirror: prefill [L, S, H, Dh] -> decode [L, B, S, H, Dh].
        let he = self.head_elems;
        let s_total = self.max_seq;
        for l in 0..self.layers {
            let src = l * s_total * he;
            let dst = (l * self.batch + slot) * s_total * he;
            let n = pre.len * he;
            self.kc[dst..dst + n].copy_from_slice(&pre.k[src..src + n]);
            self.vc[dst..dst + n].copy_from_slice(&pre.v[src..src + n]);
        }
        self.slots[slot] = Some(Slot {
            request_id,
            len: pre.len as i32,
            generated: 0,
            max_new,
            last_token: pre.first_token,
        });
        Ok(slot)
    }

    /// Run one synchronized decode step over all active slots. Returns the
    /// emissions plus the PJRT execution time.
    pub fn step(&mut self) -> Result<(Vec<Emission>, f64)> {
        if self.active() == 0 {
            return Ok((Vec::new(), 0.0));
        }
        let mut tokens = vec![tokenizer::PAD; self.batch];
        let mut lens = vec![0i32; self.batch];
        for (b, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                tokens[b] = s.last_token;
                lens[b] = s.len;
            }
        }
        let (kc_l, vc_l) = match self.cache_lits.take() {
            Some(t) => t,
            None => {
                let dims = self.decode_dims();
                (
                    vec_to_literal(&self.kc, &dims)?,
                    vec_to_literal(&self.vc, &dims)?,
                )
            }
        };
        let step = self.rt.decode_step(&tokens, &kc_l, &vc_l, &lens)?;
        self.cache_lits = Some((step.k_caches, step.v_caches));
        self.vecs_stale = true;
        let vocab = self.rt.meta.model.vocab;
        let mut emissions = Vec::new();
        for b in 0..self.batch {
            let Some(slot) = self.slots[b].as_mut() else {
                continue;
            };
            let logits = &step.logits[b * vocab..(b + 1) * vocab];
            let tok = sampler::sample(logits, self.sampling, &mut self.rng);
            slot.len += 1;
            slot.generated += 1;
            slot.last_token = tok;
            let done = slot.generated >= slot.max_new
                || tok == tokenizer::EOS
                || slot.len as usize >= self.max_seq - 1;
            emissions.push(Emission {
                request_id: slot.request_id,
                token: tok,
                done,
            });
            if done {
                self.slots[b] = None;
            }
        }
        Ok((emissions, step.exec_time))
    }

    fn decode_dims(&self) -> Vec<i64> {
        let m = &self.rt.meta.model;
        vec![
            m.n_layers as i64,
            self.batch as i64,
            m.max_seq as i64,
            m.n_heads as i64,
            m.d_head as i64,
        ]
    }
}

fn round_up(x: usize, to: usize) -> usize {
    (x + to - 1) / to * to
}

fn literal_to_vec(l: &Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}

fn vec_to_literal(v: &[f32], dims: &[i64]) -> Result<Literal> {
    Literal::vec1(v)
        .reshape(dims)
        .map_err(|e| anyhow!("{e:?}"))
}
