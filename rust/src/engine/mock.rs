//! Dependency-free mock engine: the same slot/step/prefill contract as
//! [`super::MiniEngine`], but forward passes are `thread::sleep`s sized by
//! a small analytic cost model instead of PJRT executions.
//!
//! This is what makes the serving frontend, the load generator, CI smoke
//! jobs and the concurrency integration tests runnable on a bare checkout
//! — no `make artifacts`, no `xla` crate, but real wall-clock contention:
//! the scheduler sees genuine `EndForward` timings and genuinely busy
//! instances, so buffering/flow-control behaviour is exercised end to end.

use super::{Emission, EngineBackend, PrefillOutcome};
use crate::util::Rng;
use anyhow::{anyhow, bail, Result};

/// Cost model + shape knobs for the mock engine.
#[derive(Debug, Clone, Copy)]
pub struct MockEngineConfig {
    /// Fixed per-prefill-pass overhead, seconds.
    pub t_prefill_base: f64,
    /// Marginal prefill cost per prompt token, seconds.
    pub t_prefill_per_token: f64,
    /// Cost of one batched decode step, seconds.
    pub t_decode_step: f64,
    /// Simulated chunk size (drives the reported pass count).
    pub chunk: u32,
    /// Multiplicative execution-time jitter in `[1-j, 1+j]`.
    pub jitter: f64,
    /// Synthetic KV elements per prompt token, per cache half (0 = empty
    /// caches). Deterministic per prompt, so the whole prefill→decode KV
    /// handoff — chunked segments, wire codecs, direct transfer, byte
    /// accounting — is exercised on a bare checkout with content every
    /// topology reproduces identically.
    pub kv_elems_per_token: usize,
}

impl Default for MockEngineConfig {
    fn default() -> Self {
        MockEngineConfig {
            t_prefill_base: 0.008,
            t_prefill_per_token: 2e-5,
            t_decode_step: 0.004,
            chunk: 512,
            jitter: 0.1,
            kv_elems_per_token: 16,
        }
    }
}

/// Deterministic synthetic KV for a prompt: piecewise-constant values
/// derived from prompt content — realistic enough to exercise fp16
/// rounding, structured enough that LZ compression has real wins (the
/// run length mirrors attention caches' repeated heads / padding).
fn synth_kv(prompt: &[i32], elems: usize) -> (Vec<f32>, Vec<f32>) {
    let n = prompt.len() * elems;
    let mut k = Vec::with_capacity(n);
    let mut v = Vec::with_capacity(n);
    for i in 0..n {
        let t = prompt[i % prompt.len()] as f32;
        k.push((t + (i / 7) as f32 * 0.5) * 0.125);
        v.push((t - (i / 5) as f32 * 0.25) * 0.0625);
    }
    (k, v)
}

#[derive(Debug, Clone, Copy)]
struct MockSlot {
    request_id: u64,
    generated: u32,
    max_new: u32,
    last_token: i32,
}

/// Sleep-based engine implementing [`EngineBackend`].
pub struct MockEngine {
    cfg: MockEngineConfig,
    slots: Vec<Option<MockSlot>>,
    rng: Rng,
}

impl MockEngine {
    /// Engine with `batch` decode slots (use 1 for prefill-only workers).
    pub fn new(cfg: MockEngineConfig, batch: u32, seed: u64) -> Self {
        MockEngine {
            cfg,
            slots: vec![None; batch.max(1) as usize],
            rng: Rng::new(seed),
        }
    }

    fn jittered(&mut self, t: f64) -> f64 {
        let j = self.cfg.jitter.clamp(0.0, 0.9);
        t * self.rng.uniform(1.0 - j, 1.0 + j)
    }

    /// Deterministic "model output" for a prompt: a byte-range token
    /// derived from its content, so generations are reproducible and
    /// decode to printable text.
    fn first_token_of(prompt: &[i32]) -> i32 {
        let sum: i64 = prompt.iter().map(|&t| t as i64).sum();
        0x20 + (sum % 0x5f) as i32 // printable ASCII 0x20..=0x7e
    }

    fn next_token(prev: i32) -> i32 {
        0x20 + (prev - 0x20 + 1).rem_euclid(0x5f)
    }
}

impl EngineBackend for MockEngine {
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOutcome> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let cost = self.cfg.t_prefill_base
            + self.cfg.t_prefill_per_token * prompt.len() as f64;
        let cost = self.jittered(cost);
        std::thread::sleep(std::time::Duration::from_secs_f64(cost));
        let (k, v) = synth_kv(prompt, self.cfg.kv_elems_per_token);
        Ok(PrefillOutcome {
            first_token: Self::first_token_of(prompt),
            len: prompt.len(),
            k,
            v,
            exec_time: cost,
            passes: (prompt.len() as u32).div_ceil(self.cfg.chunk.max(1)),
        })
    }

    fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    fn active(&self) -> usize {
        self.slots.len() - self.free_slots()
    }

    fn admit(&mut self, pre: &PrefillOutcome, max_new: u32, request_id: u64) -> Result<usize> {
        let slot = self
            .slots
            .iter()
            .position(Option::is_none)
            .ok_or_else(|| anyhow!("no free decode slot"))?;
        self.slots[slot] = Some(MockSlot {
            request_id,
            generated: 0,
            max_new: max_new.max(1),
            last_token: pre.first_token,
        });
        Ok(slot)
    }

    fn step(&mut self) -> Result<(Vec<Emission>, f64)> {
        if self.active() == 0 {
            return Ok((Vec::new(), 0.0));
        }
        let cost = self.jittered(self.cfg.t_decode_step);
        std::thread::sleep(std::time::Duration::from_secs_f64(cost));
        let mut emissions = Vec::new();
        for s in self.slots.iter_mut() {
            let Some(slot) = s.as_mut() else { continue };
            let tok = Self::next_token(slot.last_token);
            slot.last_token = tok;
            slot.generated += 1;
            let done = slot.generated >= slot.max_new;
            emissions.push(Emission {
                request_id: slot.request_id,
                token: tok,
                done,
            });
            if done {
                *s = None;
            }
        }
        Ok((emissions, cost))
    }

    fn abort_all(&mut self) {
        self.slots.iter_mut().for_each(|s| *s = None);
    }

    fn release(&mut self, request_id: u64) -> Option<u32> {
        let s = self
            .slots
            .iter_mut()
            .find(|s| s.map(|s| s.request_id) == Some(request_id))?;
        let remaining = s.map(|s| s.max_new.saturating_sub(s.generated))?;
        *s = None;
        Some(remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> MockEngineConfig {
        MockEngineConfig {
            t_prefill_base: 0.0,
            t_prefill_per_token: 0.0,
            t_decode_step: 0.0,
            chunk: 128,
            jitter: 0.0,
            kv_elems_per_token: 8,
        }
    }

    #[test]
    fn prefill_reports_chunk_passes() {
        let mut e = MockEngine::new(quick_cfg(), 1, 1);
        let pre = e.prefill(&[7; 300]).unwrap();
        assert_eq!(pre.len, 300);
        assert_eq!(pre.passes, 3); // ceil(300/128)
        assert!((0x20..0x7f).contains(&pre.first_token));
        assert_eq!(pre.k.len(), 300 * 8, "synthetic KV sized per config");
        assert_eq!(pre.v.len(), 300 * 8);
    }

    #[test]
    fn synthetic_kv_is_deterministic_per_prompt() {
        let mut a = MockEngine::new(quick_cfg(), 1, 1);
        let mut b = MockEngine::new(quick_cfg(), 1, 42);
        let (pa, pb) = (a.prefill(&[3, 9, 27]).unwrap(), b.prefill(&[3, 9, 27]).unwrap());
        assert_eq!(pa.k, pb.k, "KV must not depend on engine seed");
        assert_eq!(pa.v, pb.v);
    }

    #[test]
    fn decode_runs_each_slot_to_its_budget() {
        let mut e = MockEngine::new(quick_cfg(), 4, 1);
        let p1 = e.prefill(&[1, 2, 3]).unwrap();
        let p2 = e.prefill(&[4, 5]).unwrap();
        e.admit(&p1, 2, 10).unwrap();
        e.admit(&p2, 5, 11).unwrap();
        assert_eq!(e.active(), 2);
        let mut per_req = std::collections::HashMap::new();
        while e.active() > 0 {
            let (em, _) = e.step().unwrap();
            for x in em {
                *per_req.entry(x.request_id).or_insert(0u32) += 1;
            }
        }
        assert_eq!(per_req[&10], 2);
        assert_eq!(per_req[&11], 5);
        assert_eq!(e.free_slots(), 4);
    }

    #[test]
    fn generation_is_deterministic_per_prompt() {
        let mut a = MockEngine::new(quick_cfg(), 1, 1);
        let mut b = MockEngine::new(quick_cfg(), 1, 99);
        assert_eq!(
            a.prefill(&[9, 9, 9]).unwrap().first_token,
            b.prefill(&[9, 9, 9]).unwrap().first_token,
        );
    }

    #[test]
    fn release_returns_unconsumed_budget_and_frees_the_slot() {
        let mut e = MockEngine::new(quick_cfg(), 1, 1);
        let p = e.prefill(&[1, 2]).unwrap();
        e.admit(&p, 5, 9).unwrap();
        e.step().unwrap();
        e.step().unwrap();
        assert_eq!(e.release(9), Some(3), "5 budgeted, 2 generated");
        assert_eq!(e.free_slots(), 1);
        assert_eq!(e.release(9), None, "double release is safe");
        // The freed slot is immediately reusable, and a re-admission
        // seeded with the last emitted token continues the same
        // deterministic chain — the migration contiguity invariant.
        let cont = PrefillOutcome {
            first_token: MockEngine::next_token(MockEngine::next_token(p.first_token)),
            ..e.prefill(&[1, 2]).unwrap()
        };
        e.admit(&cont, 3, 9).unwrap();
        let (em, _) = e.step().unwrap();
        assert_eq!(
            em[0].token,
            MockEngine::next_token(cont.first_token),
            "stream resumes exactly where it left off"
        );
    }

    #[test]
    fn admit_rejects_when_full() {
        let mut e = MockEngine::new(quick_cfg(), 1, 1);
        let p = e.prefill(&[1]).unwrap();
        e.admit(&p, 1, 1).unwrap();
        assert!(e.admit(&p, 1, 2).is_err());
    }
}
