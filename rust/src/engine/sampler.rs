//! Token sampling over model logits.

use crate::util::Rng;

/// Sampling policy.
#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    /// Argmax.
    Greedy,
    /// Softmax with temperature (> 0).
    Temperature(f64),
}

/// Draw a token id from `logits` under the policy.
pub fn sample(logits: &[f32], policy: Sampling, rng: &mut Rng) -> i32 {
    match policy {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature(t) => {
            let t = t.max(1e-4);
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let weights: Vec<f64> = logits
                .iter()
                .map(|&x| ((x as f64 - m) / t).exp())
                .collect();
            let total: f64 = weights.iter().sum();
            let mut u = rng.f64() * total;
            for (i, w) in weights.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    return i as i32;
                }
            }
            (weights.len() - 1) as i32
        }
    }
}

/// Index of the maximum logit (first on ties).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(1);
        let logits = [0.0f32, 3.0, -1.0, 2.9];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn temperature_respects_distribution() {
        let mut rng = Rng::new(2);
        // One dominant logit: low temperature should almost always pick it.
        let logits = [0.0f32, 8.0, 0.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample(&logits, Sampling::Temperature(0.5), &mut rng) == 1)
            .count();
        assert!(hits > 190, "{hits}");
        // Very high temperature spreads out.
        let spread = (0..200)
            .filter(|_| sample(&logits, Sampling::Temperature(100.0), &mut rng) != 1)
            .count();
        assert!(spread > 50, "{spread}");
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 1.0, 1.0]), 0);
    }
}
