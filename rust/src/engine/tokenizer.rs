//! Byte-level tokenizer for the nano-MoE model (vocab 512 = 256 bytes +
//! specials). No merges: deterministic, reversible, dependency-free —
//! adequate for a randomly-initialized research model where text quality
//! is not the subject.

/// Beginning-of-sequence token.
pub const BOS: i32 = 256;
/// End-of-sequence token.
pub const EOS: i32 = 257;
/// Padding token (inactive decode slots).
pub const PAD: i32 = 258;

/// Encode text as `[BOS, bytes...]`.
pub fn encode(text: &str) -> Vec<i32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.bytes().map(|b| b as i32));
    out
}

/// Decode token ids back to text (specials dropped; invalid bytes become
/// U+FFFD via lossy UTF-8).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let ids = encode("hello, world");
        assert_eq!(ids[0], BOS);
        assert_eq!(decode(&ids), "hello, world");
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo → wörld";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_dropped() {
        assert_eq!(decode(&[BOS, 104, 105, EOS, PAD]), "hi");
    }

    #[test]
    fn ids_fit_vocab() {
        for id in encode("any text at all") {
            assert!((0..512).contains(&id));
        }
    }
}
