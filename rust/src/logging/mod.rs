//! Tiny structured logger behind the `log` facade.
//!
//! Reads `SBS_LOG` (error|warn|info|debug|trace, default `info`) and writes
//! `[elapsed] LEVEL target: message` lines to stderr. Installed once by the
//! CLI entrypoints; library code only uses the `log` macros.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::io::Write;
use std::sync::Once;
use std::time::Instant;

struct StderrLogger {
    epoch: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.epoch.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:10.4}] {lvl} {}: {}",
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent). Level comes from `SBS_LOG` or the
/// `default` argument.
pub fn init(default: LevelFilter) {
    INIT.call_once(|| {
        let level = std::env::var("SBS_LOG")
            .ok()
            .and_then(|s| parse_level(&s))
            .unwrap_or(default);
        let logger = Box::leak(Box::new(StderrLogger {
            epoch: Instant::now(),
        }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
    });
}

/// Parse a level name (case-insensitive).
pub fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level("INFO"), Some(LevelFilter::Info));
        assert_eq!(parse_level("debug"), Some(LevelFilter::Debug));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn level_parsing_full_table() {
        // Every level the module doc advertises, case-insensitively.
        let table = [
            ("off", LevelFilter::Off),
            ("error", LevelFilter::Error),
            ("warn", LevelFilter::Warn),
            ("info", LevelFilter::Info),
            ("debug", LevelFilter::Debug),
            ("trace", LevelFilter::Trace),
        ];
        for (name, want) in table {
            assert_eq!(parse_level(name), Some(want), "{name}");
            assert_eq!(parse_level(&name.to_ascii_uppercase()), Some(want));
        }
        // No silent fallback for near-misses: the caller decides defaults.
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level(" info"), None);
        assert_eq!(parse_level("warning"), None);
    }

    #[test]
    fn init_idempotent() {
        init(LevelFilter::Warn);
        init(LevelFilter::Trace); // second call is a no-op
        log::info!("smoke");
    }
}
