//! Regeneration harness for every table and figure in the paper's
//! evaluation (§5). Each `run_*` function executes the corresponding
//! simulated experiment, prints the paper-style rows, and returns the
//! measurements as JSON for EXPERIMENTS.md bookkeeping.
//!
//! | Function | Paper artifact | Headline claim |
//! |---|---|---|
//! | [`run_fig6a`] | Fig. 6(a) | TTFT −30..40% at ≤80% load (short inputs) |
//! | [`run_fig6b`] | Fig. 6(b) | advantage holds for 3K–64K inputs |
//! | [`run_table1`] | Table 1 | chunk util 52→88%, QPS +12.9..22.8% |
//! | [`run_fig7`] | Fig. 7 | decode KV ±1σ band ~40% tighter |
//! | [`run_fig8`] | Fig. 8 | decode throughput +15% |

use crate::cluster::sim::{SimReport, Simulation};
use crate::config;
use crate::json::Json;

/// Default seed for figure runs (deterministic).
pub const FIG_SEED: u64 = 2025;

/// Scale factor for quick runs (`SBS_FIG_QUICK=1` shortens horizons ~6×;
/// used by CI/tests — published numbers use the full horizon).
fn horizon_scale() -> f64 {
    if std::env::var("SBS_FIG_QUICK").as_deref() == Ok("1") {
        1.0 / 6.0
    } else {
        1.0
    }
}

fn scale_cfg(mut cfg: config::SimConfig) -> config::SimConfig {
    let s = horizon_scale();
    cfg.workload.duration *= s;
    cfg.warmup *= s;
    cfg
}

/// Fig. 6(a): mean TTFT and device-queue latency vs load (short inputs).
pub fn run_fig6a(seed: u64) -> Json {
    println!("\n== Figure 6(a): TTFT vs load — input 0–3K (mean 1K), chunk 3K, 3P1D ==");
    println!(
        "{:<8} {:>14} {:>14} {:>9}  {:>16} {:>16}",
        "load", "TTFT base(ms)", "TTFT SBS(ms)", "ΔTTFT", "devq base(ms)", "devq SBS(ms)"
    );
    let mut rows = Vec::new();
    for load in [0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let base = Simulation::run(&scale_cfg(config::fig6a(load, false, seed)));
        let sbs = Simulation::run(&scale_cfg(config::fig6a(load, true, seed)));
        let tb = base.report.ttft.mean_ms();
        let ts = sbs.report.ttft.mean_ms();
        let delta = (tb - ts) / tb * 100.0;
        println!(
            "{:<8} {:>14.1} {:>14.1} {:>8.1}%  {:>16.1} {:>16.1}",
            format!("{:.0}%", load * 100.0),
            tb,
            ts,
            delta,
            base.report.device_queue.mean_ms(),
            sbs.report.device_queue.mean_ms(),
        );
        rows.push(Json::obj(vec![
            ("load", Json::from(load)),
            ("ttft_base_ms", Json::from(tb)),
            ("ttft_sbs_ms", Json::from(ts)),
            ("ttft_delta_pct", Json::from(delta)),
            ("devq_base_ms", Json::from(base.report.device_queue.mean_ms())),
            ("devq_sbs_ms", Json::from(sbs.report.device_queue.mean_ms())),
        ]));
    }
    Json::obj(vec![("fig6a", Json::Arr(rows))])
}

/// Fig. 6(b): long-context variant (3K–64K, chunk 16K).
pub fn run_fig6b(seed: u64) -> Json {
    println!("\n== Figure 6(b): TTFT vs load — input 3K–64K (mean 6.7K), chunk 16K ==");
    println!(
        "{:<8} {:>14} {:>14} {:>9}  {:>14} {:>14}",
        "load", "TTFT base(ms)", "TTFT SBS(ms)", "ΔTTFT", "p99 base(ms)", "p99 SBS(ms)"
    );
    let mut rows = Vec::new();
    for load in [0.4, 0.6, 0.8, 1.0] {
        let base = Simulation::run(&scale_cfg(config::fig6b(load, false, seed)));
        let sbs = Simulation::run(&scale_cfg(config::fig6b(load, true, seed)));
        let tb = base.report.ttft.mean_ms();
        let ts = sbs.report.ttft.mean_ms();
        let delta = (tb - ts) / tb * 100.0;
        println!(
            "{:<8} {:>14.1} {:>14.1} {:>8.1}%  {:>14.1} {:>14.1}",
            format!("{:.0}%", load * 100.0),
            tb,
            ts,
            delta,
            base.report.ttft.percentile_ms(99.0),
            sbs.report.ttft.percentile_ms(99.0),
        );
        rows.push(Json::obj(vec![
            ("load", Json::from(load)),
            ("ttft_base_ms", Json::from(tb)),
            ("ttft_sbs_ms", Json::from(ts)),
            ("ttft_delta_pct", Json::from(delta)),
            ("p99_base_ms", Json::from(base.report.ttft.percentile_ms(99.0))),
            ("p99_sbs_ms", Json::from(sbs.report.ttft.percentile_ms(99.0))),
        ]));
    }
    Json::obj(vec![("fig6b", Json::Arr(rows))])
}

/// Find the max QPS whose mean TTFT meets `slo_s`, by bisection.
fn max_qps_under_slo(c_chunk: u32, staggered: bool, slo_s: f64, seed: u64) -> (f64, SimReport) {
    let (mut lo, mut hi) = (10.0f64, 400.0f64);
    let mut best: Option<(f64, SimReport)> = None;
    for _ in 0..10 {
        let mid = 0.5 * (lo + hi);
        let mut cfg = scale_cfg(config::table1(c_chunk, mid, staggered, seed));
        // An over-saturated run that can't drain within 3× the horizon has
        // failed the SLO regardless — don't simulate its whole backlog.
        cfg.max_time = cfg.workload.duration * 3.0;
        let rep = Simulation::run(&cfg);
        let unfinished = rep.offered - rep.completed;
        // SLO: mean TTFT within budget, nothing rejected by flow control,
        // nothing stranded at sim end.
        let ok = rep.report.ttft.mean() <= slo_s && unfinished == 0 && rep.report.rejected == 0;
        if ok {
            best = Some((mid, rep));
            lo = mid;
        } else {
            hi = mid;
        }
    }
    best.unwrap_or_else(|| {
        let cfg = scale_cfg(config::table1(c_chunk, lo, staggered, seed));
        (lo, Simulation::run(&cfg))
    })
}

/// Table 1: max sustainable QPS and chunk utilization under a mean-TTFT
/// SLO, batching off (immediate) vs on (SBS).
pub fn run_table1(seed: u64) -> Json {
    println!("\n== Table 1: Prefill chunk utilization & max QPS under mean-TTFT SLO ==");
    println!(
        "{:<26} {:<6} {:>8} {:>14} {:>10} {:>16}",
        "scenario", "batch", "QPS", "chunk util(%)", "ΔQPS(%)", "Δchunk util(pp)"
    );
    let mut rows = Vec::new();
    for (c_chunk, slo) in [(3072u32, 0.8f64), (5120, 1.0)] {
        let (q_off, r_off) = max_qps_under_slo(c_chunk, false, slo, seed);
        let (q_on, r_on) = max_qps_under_slo(c_chunk, true, slo, seed);
        let u_off = r_off.report.chunk_util.utilization() * 100.0;
        let u_on = r_on.report.chunk_util.utilization() * 100.0;
        let dq = (q_on - q_off) / q_off * 100.0;
        let scen = format!("Chunk {}K (TTFT≤{:.1}s)", c_chunk / 1024, slo);
        println!(
            "{:<26} {:<6} {:>8.1} {:>14.1} {:>10} {:>16}",
            scen, "Off", q_off, u_off, "—", "—"
        );
        println!(
            "{:<26} {:<6} {:>8.1} {:>14.1} {:>+9.1} {:>+15.1}",
            scen, "On", q_on, u_on, dq, u_on - u_off
        );
        rows.push(Json::obj(vec![
            ("chunk", Json::from(c_chunk)),
            ("slo_s", Json::from(slo)),
            ("qps_off", Json::from(q_off)),
            ("qps_on", Json::from(q_on)),
            ("util_off_pct", Json::from(u_off)),
            ("util_on_pct", Json::from(u_on)),
            ("delta_qps_pct", Json::from(dq)),
            ("delta_util_pp", Json::from(u_on - u_off)),
        ]));
    }
    Json::obj(vec![("table1", Json::Arr(rows))])
}

/// Fig. 7: decode KV-load dispersion across DP units over time.
pub fn run_fig7(seed: u64) -> Json {
    println!("\n== Figure 7: decode KV load distribution across DP=32 units ==");
    let qps = 40.0;
    let base = Simulation::run(&scale_cfg(config::fig7(qps, false, seed)));
    let sbs = Simulation::run(&scale_cfg(config::fig7(qps, true, seed)));
    let (mb, sb) = base.kv_band();
    let (ms, ss) = sbs.kv_band();
    println!(
        "{:<22} {:>12} {:>12} {:>16} {:>16}",
        "placement", "mean KV", "σ KV", "band lo (−1σ)", "band hi (+1σ)"
    );
    println!(
        "{:<22} {:>12.0} {:>12.0} {:>16.0} {:>16.0}",
        "baseline (RR)", mb, sb, mb - sb, mb + sb
    );
    println!(
        "{:<22} {:>12.0} {:>12.0} {:>16.0} {:>16.0}",
        "SBS (IQR+lex)", ms, ss, ms - ss, ms + ss
    );
    let reduction = (1.0 - ss / sb) * 100.0;
    println!("σ reduction: {reduction:.1}% (paper: ±1σ range reduced ~40%)");
    Json::obj(vec![(
        "fig7",
        Json::obj(vec![
            ("kv_mean_base", Json::from(mb)),
            ("kv_sigma_base", Json::from(sb)),
            ("kv_mean_sbs", Json::from(ms)),
            ("kv_sigma_sbs", Json::from(ss)),
            ("sigma_reduction_pct", Json::from(reduction)),
        ]),
    )])
}

/// Fig. 8: aggregate decode throughput, baseline vs IQR-aware placement.
///
/// Metric: **decode service rate** — tokens generated per second of decode
/// *execution* (Σ step durations). Under the EP sync barrier a step costs
/// what its straggler unit costs, so unbalanced placement inflates step
/// time for the same token count; the service rate captures exactly the
/// "parallelization bubbles → productive generation" conversion the paper
/// claims, independent of arrival limits.
pub fn run_fig8(seed: u64) -> Json {
    println!("\n== Figure 8: aggregate decode throughput (service rate) ==");
    // Slot-bound regime: offered load keeps every decode slot (b_max=35,
    // the paper's average batch) occupied, so both policies generate the
    // same tokens per step and the only variable is the straggler-driven
    // step time — the paper's throughput mechanism.
    let qps = 70.0;
    let mut base_cfg = scale_cfg(config::fig8(qps, false, seed));
    base_cfg.max_time = base_cfg.workload.duration * 2.0;
    let mut sbs_cfg = scale_cfg(config::fig8(qps, true, seed));
    sbs_cfg.max_time = sbs_cfg.workload.duration * 2.0;
    let base = Simulation::run(&base_cfg);
    let sbs = Simulation::run(&sbs_cfg);
    let tb = base.decode_tokens as f64 / base.decode_busy_s.max(1e-9);
    let ts = sbs.decode_tokens as f64 / sbs.decode_busy_s.max(1e-9);
    let delta = (ts - tb) / tb * 100.0;
    println!(
        "baseline (random): {tb:>10.0} tok/s of execution   ({} steps, mean {:.1} ms)",
        base.decode_steps,
        base.decode_busy_s / base.decode_steps.max(1) as f64 * 1e3
    );
    println!(
        "SBS (IQR+lex):     {ts:>10.0} tok/s of execution   ({} steps, mean {:.1} ms)",
        sbs.decode_steps,
        sbs.decode_busy_s / sbs.decode_steps.max(1) as f64 * 1e3
    );
    println!("Δ service rate: {delta:+.1}% (paper: +15%)");
    Json::obj(vec![(
        "fig8",
        Json::obj(vec![
            ("decode_service_base", Json::from(tb)),
            ("decode_service_sbs", Json::from(ts)),
            ("delta_pct", Json::from(delta)),
        ]),
    )])
}

/// Run every artifact; returns the merged JSON document.
pub fn run_all(seed: u64) -> Json {
    let mut merged = std::collections::BTreeMap::new();
    for j in [
        run_fig6a(seed),
        run_fig6b(seed),
        run_table1(seed),
        run_fig7(seed),
        run_fig8(seed),
    ] {
        if let Json::Obj(m) = j {
            merged.extend(m);
        }
    }
    Json::Obj(merged)
}

#[cfg(test)]
mod tests {
    // Figure runs are exercised end-to-end by `cargo bench` and the
    // integration tests; unit tests here only cover plumbing helpers.
    use super::*;

    #[test]
    fn horizon_scale_parses_env() {
        // Not setting the env var in-process: default is full scale.
        assert!(horizon_scale() > 0.0);
    }

    #[test]
    fn fig_seed_stable() {
        assert_eq!(FIG_SEED, 2025);
    }
}
