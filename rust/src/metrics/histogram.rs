//! Log-scaled latency histogram with bounded memory.
//!
//! Buckets grow geometrically from `min` to `max` (default 0.1 ms … 1000 s)
//! so percentile queries stay within ~2% relative error regardless of how
//! many samples are recorded — the right trade-off for long simulations
//! where storing every TTFT sample would dominate memory.

/// Geometric-bucket histogram over positive values.
#[derive(Debug, Clone)]
pub struct Histogram {
    min: f64,
    growth: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max_seen: f64,
    min_seen: f64,
}

impl Histogram {
    /// Histogram covering `[min, max]` with `buckets` geometric buckets.
    pub fn new(min: f64, max: f64, buckets: usize) -> Self {
        assert!(min > 0.0 && max > min && buckets >= 2);
        let growth = (max / min).powf(1.0 / buckets as f64);
        Histogram {
            min,
            growth,
            counts: vec![0; buckets + 2], // +underflow +overflow
            total: 0,
            sum: 0.0,
            max_seen: f64::NEG_INFINITY,
            min_seen: f64::INFINITY,
        }
    }

    /// Default latency histogram: 0.1 ms … 1000 s, ~2% resolution.
    pub fn latency() -> Self {
        Histogram::new(1e-4, 1e3, 800)
    }

    fn bucket(&self, x: f64) -> usize {
        if x < self.min {
            return 0; // underflow
        }
        let idx = (x / self.min).ln() / self.growth.ln();
        let idx = idx.floor() as usize + 1;
        idx.min(self.counts.len() - 1)
    }

    /// Record a sample (non-positive values clamp into the underflow
    /// bucket but still count toward mean).
    pub fn record(&mut self, x: f64) {
        let b = if x <= 0.0 { 0 } else { self.bucket(x) };
        self.counts[b] += 1;
        self.total += 1;
        self.sum += x;
        self.max_seen = self.max_seen.max(x);
        self.min_seen = self.min_seen.min(x);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact running mean.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate percentile (`p` in `[0, 100]`); exact min/max at the
    /// extremes.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return self.min_seen;
        }
        if p >= 100.0 {
            return self.max_seen;
        }
        let target = (p / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bucket_mid(i);
            }
        }
        self.max_seen
    }

    fn bucket_mid(&self, i: usize) -> f64 {
        if i == 0 {
            return self.min_seen.max(0.0).min(self.min);
        }
        let lo = self.min * self.growth.powi(i as i32 - 1);
        let hi = lo * self.growth;
        ((lo + hi) * 0.5).min(self.max_seen)
    }

    /// Merge another histogram with identical geometry.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        assert!((self.growth - other.growth).abs() < 1e-12);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max_seen = self.max_seen.max(other.max_seen);
        self.min_seen = self.min_seen.min(other.min_seen);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn empty() {
        let h = Histogram::latency();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::latency();
        h.record(0.1);
        h.record(0.3);
        assert!((h.mean() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn percentile_within_resolution() {
        let mut h = Histogram::latency();
        let mut r = Rng::new(5);
        let mut xs = Vec::new();
        for _ in 0..20_000 {
            let x = r.lognormal(-2.0, 1.0); // around 135 ms
            xs.push(x);
            h.record(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [50.0, 90.0, 99.0] {
            let exact = crate::util::stats::percentile_sorted(&xs, p);
            let approx = h.percentile(p);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.05, "p{p}: exact {exact} approx {approx}");
        }
    }

    #[test]
    fn extremes_exact() {
        let mut h = Histogram::latency();
        for x in [0.01, 0.5, 2.0] {
            h.record(x);
        }
        assert_eq!(h.percentile(0.0), 0.01);
        assert_eq!(h.percentile(100.0), 2.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        a.record(0.1);
        b.record(0.2);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 0.15).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_geometry() {
        let mut a = Histogram::new(1e-4, 1e3, 800);
        let b = Histogram::new(1e-4, 1e3, 400);
        a.merge(&b);
    }

    #[test]
    fn out_of_range_samples_clamp_but_count() {
        let mut h = Histogram::new(1.0, 100.0, 10);
        h.record(0.0); // non-positive → underflow
        h.record(0.5); // below min → underflow
        h.record(1e9); // above max → overflow
        h.record(10.0);
        assert_eq!(h.count(), 4);
        // The exact running mean includes the clamped samples verbatim.
        let want = (0.0 + 0.5 + 1e9 + 10.0) / 4.0;
        assert!((h.mean() - want).abs() / want < 1e-12);
        // Extremes stay exact even when they fell outside the bucket range.
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(100.0), 1e9);
        // Interior percentiles never report past the observed maximum.
        assert!(h.percentile(99.0) <= 1e9);
    }

    #[test]
    fn percentiles_are_monotone_in_p() {
        let mut h = Histogram::latency();
        let mut r = Rng::new(17);
        for _ in 0..5_000 {
            h.record(r.lognormal(-2.0, 1.5));
        }
        let mut prev = f64::NEG_INFINITY;
        for p in 0..=100 {
            let q = h.percentile(p as f64);
            assert!(q >= prev, "p{p}: {q} < {prev}");
            prev = q;
        }
    }

    #[test]
    fn single_value_within_bucket_resolution() {
        // One repeated sample must come back within a single bucket's
        // relative width — the ~2% resolution the module doc promises.
        let mut h = Histogram::latency();
        for _ in 0..100 {
            h.record(0.137);
        }
        for p in [10.0, 50.0, 90.0] {
            let q = h.percentile(p);
            assert!(
                (q - 0.137).abs() / 0.137 < 0.02,
                "p{p}: {q} outside bucket resolution"
            );
        }
    }
}
