//! Per-DP decode-pool occupancy and imbalance gauges (the live-cluster
//! counterpart of Fig. 7's KV-dispersion series), plus the prefill-pool
//! liveness gauges of the P/D-separated deployment.
//!
//! The dispatch core maintains the decode gauges while placing
//! sequences; the serving frontend exposes the snapshot over the wire
//! (`STATS`) so the load generator can embed it in its JSON report. The
//! headline gauge is [`DecodePoolStats::imbalance`]: max/mean of
//! per-unit busy time (sequence-seconds), 1.0 = perfectly balanced.
//!
//! With remote shards in either pool, each gauge also carries its
//! transport label, liveness and last-measured RTT, so a killed shard —
//! prefill *or* decode — is *visible* in `STATS` (and in the loadgen
//! report embedding it) rather than silently shrinking the pool. Remote
//! decode units additionally carry `engine_kv_tokens`, the shard's
//! engine-truth KV residency from `StatsReply`, as the cross-check
//! against the scheduler's own reservation ledger.

use crate::json::Json;
use crate::util::stats;

/// Occupancy gauge for one decode DP unit.
#[derive(Debug, Clone)]
pub struct DpOccupancyGauge {
    /// Unit label (`i<instance>d<dp>`).
    pub unit: String,
    /// Sequences placed on this unit so far.
    pub placed: u64,
    /// Sequences currently resident.
    pub active: u32,
    /// Peak concurrent sequences observed.
    pub peak_active: u32,
    /// Integral of `active` over time (sequence-seconds) — the per-unit
    /// busy-time the imbalance gauge compares.
    pub seq_seconds: f64,
    /// Ledger KV tokens currently charged to this unit.
    pub kv_tokens: u64,
    /// Transport carrying this unit (`local:<i>` or `<addr>#<unit>`).
    pub transport: String,
    /// Whether the unit's transport can currently receive placements
    /// (false = its shard is disconnected/dead).
    pub alive: bool,
    /// Last measured shard round-trip time, milliseconds (`None` for
    /// in-process units and not-yet-measured shards).
    pub rtt_ms: Option<f64>,
    /// Engine-truth resident KV tokens from the shard's last
    /// `StatsReply` (`None` for in-process units — the ledger *is* their
    /// truth — and shards not yet polled). Diverges from `kv_tokens` by
    /// design: the ledger charges the expected full residency up front,
    /// the engine reports what is materialized now.
    pub engine_kv_tokens: Option<u64>,
}

impl DpOccupancyGauge {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("unit", Json::from(self.unit.clone())),
            ("placed", Json::from(self.placed)),
            ("active", Json::from(self.active)),
            ("peak_active", Json::from(self.peak_active)),
            ("seq_seconds", Json::from(self.seq_seconds)),
            ("kv_tokens", Json::from(self.kv_tokens)),
            ("transport", Json::from(self.transport.clone())),
            ("alive", Json::from(self.alive)),
            ("rtt_ms", self.rtt_ms.map(Json::from).unwrap_or(Json::Null)),
            (
                "engine_kv_tokens",
                self.engine_kv_tokens.map(Json::from).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Liveness/identity gauge for one prefill instance (local or remote) —
/// what makes a killed prefill shard loud in `STATS` and the loadgen
/// report instead of a silently stalled pipeline.
#[derive(Debug, Clone)]
pub struct PrefillUnitGauge {
    /// Instance label (`p<i>`, flat pool order).
    pub unit: String,
    /// Transport carrying this instance (`prefill:<i>` or
    /// `<addr>#p<unit>`).
    pub transport: String,
    /// Whether the instance's transport can currently receive
    /// dispatches.
    pub alive: bool,
    /// Last measured shard round-trip time, milliseconds.
    pub rtt_ms: Option<f64>,
    /// Batches dispatched to this instance so far.
    pub dispatched: u64,
}

impl PrefillUnitGauge {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("unit", Json::from(self.unit.clone())),
            ("transport", Json::from(self.transport.clone())),
            ("alive", Json::from(self.alive)),
            ("rtt_ms", self.rtt_ms.map(Json::from).unwrap_or(Json::Null)),
            ("dispatched", Json::from(self.dispatched)),
        ])
    }
}

/// KV handoff wire accounting under the negotiated `--kv-wire` codec:
/// what the KV payloads cost on the wire vs their raw `f32` size, split
/// by where they landed. `wire/raw_bytes` aggregate the decode shards'
/// *inbound* KV (their `StatsReply` counters — covers both relayed
/// admits and direct peer handoffs); `relay_*` count only KV the
/// scheduler itself carried (received `KvSegment`s + sent `Admit`s), so
/// direct transfer shows up as `relay_wire_bytes ≈ 0` while the shard
/// totals keep growing.
#[derive(Debug, Clone, Default)]
pub struct KvWireGauge {
    /// Negotiated codec name (`raw` / `fp16` / `lz`).
    pub codec: String,
    /// Coded KV bytes received by decode shards.
    pub wire_bytes: u64,
    /// The same KV as raw `f32` bytes.
    pub raw_bytes: u64,
    /// Coded KV bytes that crossed the scheduler (relay path only).
    pub relay_wire_bytes: u64,
    /// Raw size of the scheduler-relayed KV.
    pub relay_raw_bytes: u64,
}

impl KvWireGauge {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("codec", Json::from(self.codec.clone())),
            ("wire_bytes", Json::from(self.wire_bytes)),
            ("raw_bytes", Json::from(self.raw_bytes)),
            ("relay_wire_bytes", Json::from(self.relay_wire_bytes)),
            ("relay_raw_bytes", Json::from(self.relay_raw_bytes)),
        ])
    }
}

/// SLO rescue + deadline outcome gauge: what the dispatch core's rescue
/// scan has done (preemptions and live migrations) and how deadlines
/// are landing. `rescue_deadline_met` counts deadline-carrying
/// sequences a rescue action touched that still finished in time — the
/// layer's headline "the rescue worked" number.
#[derive(Debug, Clone, Default)]
pub struct RescueGauge {
    /// Whether the rescue scan is enabled on this core.
    pub enabled: bool,
    /// Batch-class sequences preempted off a hot unit.
    pub preempted: u64,
    /// Endangered sequences live-migrated to a unit with headroom.
    pub migrated: u64,
    /// Deadline-carrying sequences that finished in time.
    pub deadline_met: u64,
    /// Deadline-carrying sequences that finished late.
    pub deadline_violated: u64,
    /// Of `deadline_met`, those a rescue action touched.
    pub rescue_deadline_met: u64,
}

impl RescueGauge {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::from(self.enabled)),
            ("preempted", Json::from(self.preempted)),
            ("migrated", Json::from(self.migrated)),
            ("deadline_met", Json::from(self.deadline_met)),
            ("deadline_violated", Json::from(self.deadline_violated)),
            ("rescue_deadline_met", Json::from(self.rescue_deadline_met)),
        ])
    }
}

/// Snapshot of the cluster's serving pools under one placement policy:
/// the decode DP pool's occupancy gauges plus the prefill pool's
/// liveness gauges. (Named for its decode-side origin; `STATS` exposes
/// the whole snapshot.)
#[derive(Debug, Clone)]
pub struct DecodePoolStats {
    /// Placement policy name (`load-aware` / `round-robin` / `random`).
    pub policy: String,
    /// Per-unit decode gauges, flat unit order.
    pub units: Vec<DpOccupancyGauge>,
    /// Per-instance prefill gauges, flat pool order.
    pub prefill: Vec<PrefillUnitGauge>,
    /// KV handoff wire accounting (filled by the driver's decorator; the
    /// core is transport-blind).
    pub kv_wire: KvWireGauge,
    /// SLO rescue + deadline outcome counters.
    pub rescue: RescueGauge,
}

impl DecodePoolStats {
    /// Empty snapshot (pool not yet started).
    pub fn empty(policy: &str) -> Self {
        DecodePoolStats {
            policy: policy.to_string(),
            units: Vec::new(),
            prefill: Vec::new(),
            kv_wire: KvWireGauge::default(),
            rescue: RescueGauge::default(),
        }
    }

    /// All-zero snapshot with the decode pool shape known up front (so
    /// `STATS` reports `n_units` even before the scheduler has placed
    /// anything). The `prefill` section starts empty — like
    /// `DispatchCore::decode_stats`, this leaves it for the driver's
    /// decorator, which builds it wholesale from its transports.
    pub fn zeroed(policy: &str, unit_labels: Vec<String>) -> Self {
        DecodePoolStats {
            policy: policy.to_string(),
            units: unit_labels
                .into_iter()
                .map(|unit| DpOccupancyGauge {
                    unit,
                    placed: 0,
                    active: 0,
                    peak_active: 0,
                    seq_seconds: 0.0,
                    kv_tokens: 0,
                    transport: "local".to_string(),
                    alive: true,
                    rtt_ms: None,
                    engine_kv_tokens: None,
                })
                .collect(),
            prefill: Vec::new(),
            kv_wire: KvWireGauge::default(),
            rescue: RescueGauge::default(),
        }
    }

    /// Units whose transport can currently receive placements.
    pub fn units_alive(&self) -> usize {
        self.units.iter().filter(|u| u.alive).count()
    }

    /// Prefill instances whose transport can currently receive
    /// dispatches.
    pub fn prefill_units_alive(&self) -> usize {
        self.prefill.iter().filter(|u| u.alive).count()
    }

    /// Total sequences placed across the pool.
    pub fn total_placed(&self) -> u64 {
        self.units.iter().map(|u| u.placed).sum()
    }

    /// Max/mean per-unit busy-time imbalance: 1.0 = perfectly balanced,
    /// `n_units` = everything on one unit. Falls back to placement counts
    /// when no busy time has accumulated yet; 1.0 for an empty pool.
    pub fn imbalance(&self) -> f64 {
        if self.units.is_empty() {
            return 1.0;
        }
        let mut xs: Vec<f64> = self.units.iter().map(|u| u.seq_seconds).collect();
        if xs.iter().sum::<f64>() <= 0.0 {
            xs = self.units.iter().map(|u| u.placed as f64).collect();
        }
        let mean = stats::mean(&xs);
        if mean <= 0.0 {
            return 1.0;
        }
        xs.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// JSON summary (embedded in the loadgen report and `STATS` replies).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::from(self.policy.clone())),
            ("n_units", Json::from(self.units.len())),
            ("units_alive", Json::from(self.units_alive())),
            ("imbalance", Json::from(self.imbalance())),
            ("placed", Json::from(self.total_placed())),
            (
                "units",
                Json::Arr(self.units.iter().map(|u| u.to_json()).collect()),
            ),
            (
                "prefill",
                Json::obj(vec![
                    ("n_units", Json::from(self.prefill.len())),
                    ("units_alive", Json::from(self.prefill_units_alive())),
                    (
                        "units",
                        Json::Arr(self.prefill.iter().map(|u| u.to_json()).collect()),
                    ),
                ]),
            ),
            ("kv_wire", self.kv_wire.to_json()),
            ("rescue", self.rescue.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(name: &str, placed: u64, seq_seconds: f64) -> DpOccupancyGauge {
        DpOccupancyGauge {
            unit: name.to_string(),
            placed,
            active: 0,
            peak_active: 1,
            seq_seconds,
            kv_tokens: 0,
            transport: "local".to_string(),
            alive: true,
            rtt_ms: None,
            engine_kv_tokens: None,
        }
    }

    fn prefill_unit(i: u32, alive: bool) -> PrefillUnitGauge {
        PrefillUnitGauge {
            unit: format!("p{i}"),
            transport: format!("prefill:{i}"),
            alive,
            rtt_ms: None,
            dispatched: 3,
        }
    }

    #[test]
    fn empty_pool_is_balanced() {
        assert_eq!(DecodePoolStats::empty("round-robin").imbalance(), 1.0);
    }

    #[test]
    fn imbalance_is_max_over_mean() {
        let s = DecodePoolStats {
            policy: "round-robin".into(),
            units: vec![unit("i0d0", 1, 3.0), unit("i1d0", 1, 1.0)],
            prefill: Vec::new(),
            kv_wire: KvWireGauge::default(),
            rescue: RescueGauge::default(),
        };
        assert!((s.imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn falls_back_to_placed_counts_before_busy_time() {
        let s = DecodePoolStats {
            policy: "random".into(),
            units: vec![unit("i0d0", 4, 0.0), unit("i1d0", 0, 0.0)],
            prefill: Vec::new(),
            kv_wire: KvWireGauge::default(),
            rescue: RescueGauge::default(),
        };
        assert!((s.imbalance() - 2.0).abs() < 1e-12);
        assert_eq!(s.total_placed(), 4);
    }

    #[test]
    fn json_carries_the_gauges() {
        let s = DecodePoolStats {
            policy: "load-aware".into(),
            units: vec![unit("i0d0", 2, 1.0)],
            prefill: vec![prefill_unit(0, true)],
            kv_wire: KvWireGauge {
                codec: "lz".into(),
                wire_bytes: 100,
                raw_bytes: 400,
                relay_wire_bytes: 0,
                relay_raw_bytes: 0,
            },
            rescue: RescueGauge {
                enabled: true,
                preempted: 2,
                migrated: 1,
                deadline_met: 5,
                deadline_violated: 1,
                rescue_deadline_met: 2,
            },
        };
        let j = s.to_json();
        assert_eq!(j.get("policy").and_then(|x| x.as_str()), Some("load-aware"));
        assert_eq!(j.get("n_units").and_then(|x| x.as_usize()), Some(1));
        assert_eq!(j.get("units_alive").and_then(|x| x.as_usize()), Some(1));
        assert!(j.get("imbalance").and_then(|x| x.as_f64()).is_some());
        assert_eq!(j.get("units").and_then(|x| x.as_arr()).map(|a| a.len()), Some(1));
        let u = &j.get("units").and_then(|x| x.as_arr()).unwrap()[0];
        assert_eq!(u.get("alive").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(u.get("transport").and_then(|x| x.as_str()), Some("local"));
        let p = j.get("prefill").unwrap();
        assert_eq!(p.get("n_units").and_then(|x| x.as_usize()), Some(1));
        assert_eq!(p.get("units_alive").and_then(|x| x.as_usize()), Some(1));
        let pu = &p.get("units").and_then(|x| x.as_arr()).unwrap()[0];
        assert_eq!(pu.get("transport").and_then(|x| x.as_str()), Some("prefill:0"));
        assert_eq!(pu.get("dispatched").and_then(|x| x.as_usize()), Some(3));
        let kv = j.get("kv_wire").unwrap();
        assert_eq!(kv.get("codec").and_then(|x| x.as_str()), Some("lz"));
        assert_eq!(kv.get("wire_bytes").and_then(|x| x.as_usize()), Some(100));
        assert_eq!(kv.get("raw_bytes").and_then(|x| x.as_usize()), Some(400));
        assert_eq!(kv.get("relay_wire_bytes").and_then(|x| x.as_usize()), Some(0));
        let r = j.get("rescue").unwrap();
        assert_eq!(r.get("enabled").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(r.get("preempted").and_then(|x| x.as_usize()), Some(2));
        assert_eq!(r.get("migrated").and_then(|x| x.as_usize()), Some(1));
        assert_eq!(r.get("deadline_met").and_then(|x| x.as_usize()), Some(5));
        assert_eq!(r.get("deadline_violated").and_then(|x| x.as_usize()), Some(1));
        assert_eq!(r.get("rescue_deadline_met").and_then(|x| x.as_usize()), Some(2));
    }

    #[test]
    fn dead_units_are_visible_not_silently_dropped() {
        let mut dead = unit("i1d0", 3, 1.0);
        dead.alive = false;
        dead.transport = "127.0.0.1:7501#0".into();
        dead.rtt_ms = Some(0.4);
        dead.engine_kv_tokens = Some(120);
        let s = DecodePoolStats {
            policy: "load-aware".into(),
            units: vec![unit("i0d0", 2, 2.0), dead],
            prefill: Vec::new(),
            kv_wire: KvWireGauge::default(),
            rescue: RescueGauge::default(),
        };
        assert_eq!(s.units_alive(), 1);
        let j = s.to_json();
        assert_eq!(j.get("units_alive").and_then(|x| x.as_usize()), Some(1));
        assert_eq!(j.get("n_units").and_then(|x| x.as_usize()), Some(2));
        let u = &j.get("units").and_then(|x| x.as_arr()).unwrap()[1];
        assert_eq!(u.get("alive").and_then(|x| x.as_bool()), Some(false));
        assert!(u.get("rtt_ms").and_then(|x| x.as_f64()).is_some());
        assert_eq!(u.get("engine_kv_tokens").and_then(|x| x.as_usize()), Some(120));
    }

    #[test]
    fn dead_prefill_instances_are_visible() {
        let s = DecodePoolStats {
            policy: "load-aware".into(),
            units: vec![unit("i0d0", 2, 2.0)],
            prefill: vec![prefill_unit(0, true), prefill_unit(1, false)],
            kv_wire: KvWireGauge::default(),
            rescue: RescueGauge::default(),
        };
        assert_eq!(s.prefill_units_alive(), 1);
        let j = s.to_json();
        let p = j.get("prefill").unwrap();
        assert_eq!(p.get("n_units").and_then(|x| x.as_usize()), Some(2));
        assert_eq!(p.get("units_alive").and_then(|x| x.as_usize()), Some(1));
        let pu = &p.get("units").and_then(|x| x.as_arr()).unwrap()[1];
        assert_eq!(pu.get("alive").and_then(|x| x.as_bool()), Some(false));
    }
}
