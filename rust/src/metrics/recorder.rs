//! Per-request metric collection and aggregate serving reports.

use super::Histogram;
use crate::json::Json;

/// Lifecycle timestamps of a single request (seconds; -1 = not yet).
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    /// External arrival at the scheduler frontend.
    pub t_arrival: f64,
    /// Dispatch from the scheduler to an instance (leaves the
    /// scheduler-side queue).
    pub t_dispatch: f64,
    /// First forward pass containing this request starts on-device (leaves
    /// the device-side queue).
    pub t_exec_start: f64,
    /// First output token produced (prefill for this request completed).
    pub t_first_token: f64,
    /// Final output token produced.
    pub t_done: f64,
    /// Prompt length in tokens.
    pub input_tokens: u32,
    /// Generated length in tokens.
    pub output_tokens: u32,
}

impl RequestMetrics {
    /// Fresh record at arrival time.
    pub fn arrive(t: f64, input_tokens: u32) -> Self {
        RequestMetrics {
            t_arrival: t,
            t_dispatch: -1.0,
            t_exec_start: -1.0,
            t_first_token: -1.0,
            t_done: -1.0,
            input_tokens,
            output_tokens: 0,
        }
    }

    /// Time-to-first-token: arrival → first token.
    pub fn ttft(&self) -> Option<f64> {
        (self.t_first_token >= 0.0).then(|| self.t_first_token - self.t_arrival)
    }

    /// Scheduler-side queueing: arrival → dispatch.
    pub fn sched_queue(&self) -> Option<f64> {
        (self.t_dispatch >= 0.0).then(|| self.t_dispatch - self.t_arrival)
    }

    /// Device-side queueing: dispatch → execution start. This is the HOL
    /// blocking component the paper attributes to immediate dispatch.
    pub fn device_queue(&self) -> Option<f64> {
        (self.t_exec_start >= 0.0 && self.t_dispatch >= 0.0)
            .then(|| self.t_exec_start - self.t_dispatch)
    }

    /// Mean time-per-output-token after the first.
    pub fn tpot(&self) -> Option<f64> {
        if self.t_done >= 0.0 && self.output_tokens > 1 {
            Some((self.t_done - self.t_first_token) / (self.output_tokens - 1) as f64)
        } else {
            None
        }
    }
}

/// Streaming latency statistics (histogram + exact mean).
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    hist: Histogram,
    label: String,
}

impl LatencyRecorder {
    /// New recorder with a display label (e.g. "ttft").
    pub fn new(label: &str) -> Self {
        LatencyRecorder {
            hist: Histogram::latency(),
            label: label.to_string(),
        }
    }

    /// Record one latency sample in seconds.
    pub fn record(&mut self, seconds: f64) {
        self.hist.record(seconds);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    /// Exact mean in seconds.
    pub fn mean(&self) -> f64 {
        self.hist.mean()
    }

    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean() * 1e3
    }

    /// Approximate percentile in seconds.
    pub fn percentile(&self, p: f64) -> f64 {
        self.hist.percentile(p)
    }

    /// Percentile in milliseconds.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile(p) * 1e3
    }

    /// Merge samples from another recorder.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.hist.merge(&other.hist);
    }

    /// One-line human report.
    pub fn line(&self) -> String {
        format!(
            "{}: n={} mean={:.1}ms p50={:.1}ms p90={:.1}ms p99={:.1}ms",
            self.label,
            self.count(),
            self.mean_ms(),
            self.percentile_ms(50.0),
            self.percentile_ms(90.0),
            self.percentile_ms(99.0),
        )
    }

    /// JSON summary.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::from(self.label.clone())),
            ("count", Json::from(self.count())),
            ("mean_ms", Json::from(self.mean_ms())),
            ("p50_ms", Json::from(self.percentile_ms(50.0))),
            ("p90_ms", Json::from(self.percentile_ms(90.0))),
            ("p99_ms", Json::from(self.percentile_ms(99.0))),
        ])
    }
}

/// Windowless token/request throughput counter over a time span.
#[derive(Debug, Clone, Default)]
pub struct ThroughputCounter {
    /// Completed requests.
    pub requests: u64,
    /// Prefill tokens processed.
    pub prefill_tokens: u64,
    /// Decode tokens generated.
    pub decode_tokens: u64,
    t_start: f64,
    t_end: f64,
}

impl ThroughputCounter {
    /// Start a counter at `t`.
    pub fn start(t: f64) -> Self {
        ThroughputCounter {
            t_start: t,
            t_end: t,
            ..Default::default()
        }
    }

    /// Account a completed request at time `t`.
    pub fn complete(&mut self, t: f64, prefill_tokens: u64, decode_tokens: u64) {
        self.requests += 1;
        self.prefill_tokens += prefill_tokens;
        self.decode_tokens += decode_tokens;
        self.t_end = self.t_end.max(t);
    }

    /// Account raw tokens (e.g. per forward pass) at time `t`.
    pub fn add_tokens(&mut self, t: f64, prefill: u64, decode: u64) {
        self.prefill_tokens += prefill;
        self.decode_tokens += decode;
        self.t_end = self.t_end.max(t);
    }

    /// Elapsed span in seconds.
    pub fn elapsed(&self) -> f64 {
        (self.t_end - self.t_start).max(1e-9)
    }

    /// Requests per second.
    pub fn qps(&self) -> f64 {
        self.requests as f64 / self.elapsed()
    }

    /// Prefill tokens per second.
    pub fn prefill_tps(&self) -> f64 {
        self.prefill_tokens as f64 / self.elapsed()
    }

    /// Decode tokens per second.
    pub fn decode_tps(&self) -> f64 {
        self.decode_tokens as f64 / self.elapsed()
    }
}

/// Prefill Chunk Utilization meter (Table 1): fraction of the theoretical
/// per-forward token capacity actually used, averaged over forward passes.
#[derive(Debug, Clone, Default)]
pub struct UtilizationMeter {
    used: u64,
    capacity: u64,
    passes: u64,
}

impl UtilizationMeter {
    /// Account one forward pass that used `used` of `capacity` tokens.
    pub fn record_pass(&mut self, used: u64, capacity: u64) {
        self.used += used;
        self.capacity += capacity;
        self.passes += 1;
    }

    /// Aggregate utilization in `[0, 1]`.
    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.used as f64 / self.capacity as f64
        }
    }

    /// Number of forward passes observed.
    pub fn passes(&self) -> u64 {
        self.passes
    }
}

/// Aggregate output of a serving run (simulation or real): the quantities
/// the paper's tables/figures report.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Time-to-first-token distribution.
    pub ttft: LatencyRecorder,
    /// Scheduler-side queueing delay distribution.
    pub sched_queue: LatencyRecorder,
    /// Device-side queueing delay distribution (HOL blocking).
    pub device_queue: LatencyRecorder,
    /// End-to-end latency distribution.
    pub e2e: LatencyRecorder,
    /// Token/request throughput.
    pub throughput: ThroughputCounter,
    /// Prefill chunk utilization.
    pub chunk_util: UtilizationMeter,
    /// Requests rejected by flow control.
    pub rejected: u64,
}

impl ServingReport {
    /// Empty report with the clock starting at `t`.
    pub fn new(t_start: f64) -> Self {
        ServingReport {
            ttft: LatencyRecorder::new("ttft"),
            sched_queue: LatencyRecorder::new("sched_queue"),
            device_queue: LatencyRecorder::new("device_queue"),
            e2e: LatencyRecorder::new("e2e"),
            throughput: ThroughputCounter::start(t_start),
            chunk_util: UtilizationMeter::default(),
            rejected: 0,
        }
    }

    /// Fold one finished request into the aggregates.
    pub fn absorb(&mut self, m: &RequestMetrics) {
        if let Some(x) = m.ttft() {
            self.ttft.record(x);
        }
        if let Some(x) = m.sched_queue() {
            self.sched_queue.record(x);
        }
        if let Some(x) = m.device_queue() {
            self.device_queue.record(x);
        }
        if m.t_done >= 0.0 {
            self.e2e.record(m.t_done - m.t_arrival);
            self.throughput.complete(
                m.t_done,
                m.input_tokens as u64,
                m.output_tokens as u64,
            );
        }
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        format!(
            "{}\n{}\n{}\n{}\nthroughput: qps={:.2} prefill_tps={:.0} decode_tps={:.0} rejected={}\nchunk_util: {:.1}% over {} passes",
            self.ttft.line(),
            self.sched_queue.line(),
            self.device_queue.line(),
            self.e2e.line(),
            self.throughput.qps(),
            self.throughput.prefill_tps(),
            self.throughput.decode_tps(),
            self.rejected,
            self.chunk_util.utilization() * 100.0,
            self.chunk_util.passes(),
        )
    }

    /// JSON summary for trace/analysis dumps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ttft", self.ttft.to_json()),
            ("sched_queue", self.sched_queue.to_json()),
            ("device_queue", self.device_queue.to_json()),
            ("e2e", self.e2e.to_json()),
            ("qps", Json::from(self.throughput.qps())),
            ("prefill_tps", Json::from(self.throughput.prefill_tps())),
            ("decode_tps", Json::from(self.throughput.decode_tps())),
            ("chunk_util", Json::from(self.chunk_util.utilization())),
            ("rejected", Json::from(self.rejected)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_req() -> RequestMetrics {
        let mut m = RequestMetrics::arrive(10.0, 1000);
        m.t_dispatch = 10.2;
        m.t_exec_start = 10.5;
        m.t_first_token = 10.9;
        m.t_done = 12.9;
        m.output_tokens = 101;
        m
    }

    #[test]
    fn request_decomposition() {
        let m = sample_req();
        assert!((m.ttft().unwrap() - 0.9).abs() < 1e-12);
        assert!((m.sched_queue().unwrap() - 0.2).abs() < 1e-12);
        assert!((m.device_queue().unwrap() - 0.3).abs() < 1e-12);
        assert!((m.tpot().unwrap() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn incomplete_request_none() {
        let m = RequestMetrics::arrive(0.0, 10);
        assert!(m.ttft().is_none());
        assert!(m.tpot().is_none());
        assert!(m.device_queue().is_none());
    }

    #[test]
    fn report_absorb() {
        let mut r = ServingReport::new(10.0);
        r.absorb(&sample_req());
        assert_eq!(r.ttft.count(), 1);
        assert_eq!(r.throughput.requests, 1);
        assert_eq!(r.throughput.prefill_tokens, 1000);
        assert!(r.render().contains("ttft"));
    }

    #[test]
    fn utilization_meter() {
        let mut u = UtilizationMeter::default();
        u.record_pass(1500, 3000);
        u.record_pass(3000, 3000);
        assert!((u.utilization() - 0.75).abs() < 1e-12);
        assert_eq!(u.passes(), 2);
    }

    #[test]
    fn throughput_counter() {
        let mut t = ThroughputCounter::start(0.0);
        t.complete(2.0, 100, 50);
        t.complete(4.0, 100, 50);
        assert!((t.qps() - 0.5).abs() < 1e-9);
        assert!((t.decode_tps() - 25.0).abs() < 1e-9);
    }
}
