//! Serving metrics: TTFT / TPOT / queueing-time recorders, log-scaled
//! latency histograms, chunk-utilization and throughput accounting.
//!
//! These are the quantities the paper's evaluation reports: mean TTFT and
//! internal queuing latency (Fig. 6), Prefill Chunk Utilization and max
//! sustainable QPS (Table 1), per-DP KV-load dispersion (Fig. 7) and
//! aggregate decode throughput (Fig. 8).

mod decode_pool;
mod histogram;
mod recorder;

pub use decode_pool::{
    DecodePoolStats, DpOccupancyGauge, KvWireGauge, PrefillUnitGauge, RescueGauge,
};
pub use histogram::Histogram;
pub use recorder::{
    LatencyRecorder, RequestMetrics, ServingReport, ThroughputCounter, UtilizationMeter,
};
