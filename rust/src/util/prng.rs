//! Deterministic pseudo-random number generation.
//!
//! The offline registry ships no `rand` crate, so we implement the small
//! set of generators the system needs: SplitMix64 for seeding,
//! xoshiro256** as the workhorse, and the handful of distributions used by
//! the workload generators (uniform, exponential, normal, log-normal,
//! Pareto-ish heavy tails, Zipf).

/// SplitMix64 step — used to expand a single `u64` seed into the
/// xoshiro256** state. Passes BigCrush when used as a generator itself.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator with the distribution helpers the workload and
/// property-test layers need. Deterministic given a seed.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // Guard against the all-zero state (cannot occur for real seeds but
        // cheap to rule out).
        if s == [0, 0, 0, 0] {
            s[0] = 0x1;
        }
        Rng { s }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`). Inter-arrival gaps
    /// of a Poisson process.
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1 - f64() is in (0, 1], so ln() is finite.
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box–Muller (one value per call; we do not cache
    /// the second — throughput is irrelevant here and statelessness keeps
    /// replay simple).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64(); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterised by the *underlying* normal's mu/sigma.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto (Lomax-style heavy tail) with scale `x_m` and shape `alpha`.
    #[inline]
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        x_m / (1.0 - self.f64()).powf(1.0 / alpha)
    }

    /// Gamma(shape `k`, scale `θ`) via Marsaglia–Tsang squeeze (mean
    /// `kθ`). Shapes below 1 use the boosting identity
    /// `Gamma(k) = Gamma(k+1) · U^{1/k}` — that sub-1 regime (CV > 1) is
    /// what the bursty arrival generator draws from.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            let u = 1.0 - self.f64(); // (0, 1]
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = 1.0 - self.f64(); // (0, 1], ln is finite
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * scale;
            }
        }
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s`, via inverse-CDF
    /// over precomputable weights. O(n) per call — fine for the prefix
    /// workload generator's modest n.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent generator (for per-component streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_close_both_regimes() {
        let mut r = Rng::new(29);
        let n = 100_000;
        // Sub-1 shape (the bursty-arrival regime) exercises the boost.
        let mean: f64 = (0..n).map(|_| r.gamma(0.25, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "k=0.25 mean {mean}");
        let mean: f64 = (0..n).map(|_| r.gamma(3.0, 0.5)).sum::<f64>() / n as f64;
        assert!((mean - 1.5).abs() < 0.05, "k=3 mean {mean}");
    }

    #[test]
    fn gamma_is_nonnegative_and_bursty_shape_has_high_cv() {
        let mut r = Rng::new(31);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(0.25, 4.0)).collect();
        assert!(xs.iter().all(|&x| x >= 0.0));
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let cv = var.sqrt() / mean;
        // Theoretical CV = 1/√k = 2; allow sampling slack.
        assert!(cv > 1.5, "cv {cv}");
    }

    #[test]
    fn zipf_monotone_head() {
        let mut r = Rng::new(17);
        let mut counts = vec![0usize; 10];
        for _ in 0..50_000 {
            counts[r.zipf(10, 1.1)] += 1;
        }
        assert!(counts[0] > counts[4], "{counts:?}");
        assert!(counts[1] > counts[8], "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut r = Rng::new(23);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }
}
