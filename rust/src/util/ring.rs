//! Fixed-capacity sliding windows.
//!
//! [`SlidingWindow`] is the W_stats structure of paper Algorithm 1: a
//! bounded FIFO of forward-pass execution times with an O(1) running mean
//! (the "moving average filter" that smooths T̄_fwd).

use std::collections::VecDeque;

/// Bounded FIFO of `f64` samples with an O(1) running sum/mean.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    buf: VecDeque<f64>,
    cap: usize,
    sum: f64,
}

impl SlidingWindow {
    /// Create a window holding at most `cap` samples (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "window capacity must be >= 1");
        SlidingWindow {
            buf: VecDeque::with_capacity(cap),
            cap,
            sum: 0.0,
        }
    }

    /// Push a sample, evicting the oldest if at capacity (paper Alg. 1
    /// lines 15–18).
    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.cap {
            if let Some(old) = self.buf.pop_front() {
                self.sum -= old;
            }
        }
        self.buf.push_back(x);
        self.sum += x;
        // Periodically re-accumulate to bound float drift in long runs.
        if self.buf.len() == self.cap && self.sum.abs() > 1e12 {
            self.sum = self.buf.iter().sum();
        }
    }

    /// Number of samples currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Running mean; `None` while empty.
    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else {
            Some(self.sum / self.buf.len() as f64)
        }
    }

    /// Latest sample, if any.
    pub fn last(&self) -> Option<f64> {
        self.buf.back().copied()
    }

    /// Iterate oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = f64> + '_ {
        self.buf.iter().copied()
    }

    /// Drop all samples.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.sum = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_mean_none() {
        let w = SlidingWindow::new(4);
        assert!(w.mean().is_none());
        assert!(w.is_empty());
    }

    #[test]
    fn mean_under_capacity() {
        let mut w = SlidingWindow::new(4);
        w.push(1.0);
        w.push(3.0);
        assert_eq!(w.mean(), Some(2.0));
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn eviction_at_capacity() {
        let mut w = SlidingWindow::new(3);
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        // holds [2,3,4]
        assert_eq!(w.len(), 3);
        assert_eq!(w.mean(), Some(3.0));
        assert_eq!(w.last(), Some(4.0));
    }

    #[test]
    fn clear_resets() {
        let mut w = SlidingWindow::new(2);
        w.push(5.0);
        w.clear();
        assert!(w.mean().is_none());
        w.push(7.0);
        assert_eq!(w.mean(), Some(7.0));
    }

    #[test]
    fn iter_order() {
        let mut w = SlidingWindow::new(2);
        w.push(1.0);
        w.push(2.0);
        w.push(3.0);
        let v: Vec<f64> = w.iter().collect();
        assert_eq!(v, vec![2.0, 3.0]);
    }
}
