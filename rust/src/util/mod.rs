//! Foundational utilities: deterministic PRNG, statistics, sliding windows
//! and clock abstractions.
//!
//! Everything here is dependency-free and deterministic so that simulations
//! and property tests are exactly reproducible from a seed.

pub mod clock;
pub mod prng;
pub mod ring;
pub mod stats;

pub use clock::{Clock, ManualClock, RealClock};
pub use prng::Rng;
pub use ring::SlidingWindow;
pub use stats::Summary;
