//! Descriptive statistics: means, percentiles, IQR, summaries.
//!
//! The IQR helpers implement exactly the quartile definition used by
//! Algorithm 3 of the paper (linear interpolation between closest ranks,
//! numpy's default), so the decode scheduler's outlier mask is
//! reproducible against a numpy reference.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Sample (n−1) standard deviation — the unbiased dispersion estimate for
/// small replica counts (bench noise thresholds); 0.0 below two samples.
pub fn sample_stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile `p` in `[0, 100]` of an **unsorted** slice, with linear
/// interpolation between closest ranks (numpy default). O(n log n).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile of an already-sorted slice. O(1).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Interquartile range statistics for outlier masking (paper Algorithm 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Iqr {
    /// 25th percentile.
    pub q1: f64,
    /// 75th percentile.
    pub q3: f64,
}

impl Iqr {
    /// Compute Q1/Q3 of an unsorted sample.
    pub fn of(xs: &[f64]) -> Iqr {
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Iqr {
            q1: percentile_sorted(&v, 25.0),
            q3: percentile_sorted(&v, 75.0),
        }
    }

    /// The range itself, `Q3 - Q1`.
    pub fn range(&self) -> f64 {
        self.q3 - self.q1
    }

    /// The paper's dynamic exclusion threshold `Q3 + k * IQR`.
    pub fn outlier_threshold(&self, k: f64) -> f64 {
        self.q3 + k * self.range()
    }
}

/// A one-pass summary of a sample: count, mean, stddev and key percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    /// Number of samples.
    pub count: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Median (p50).
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarise a sample (empty input yields all-zero summary).
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count: v.len(),
            mean: mean(&v),
            std: stddev(&v),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: v[v.len() - 1],
        }
    }

    /// Render as a compact single-line report, scaled by `unit` with the
    /// given suffix (e.g. `1e3, "ms"` for values held in seconds).
    pub fn line(&self, unit: f64, suffix: &str) -> String {
        format!(
            "n={} mean={:.2}{s} p50={:.2}{s} p90={:.2}{s} p99={:.2}{s} max={:.2}{s}",
            self.count,
            self.mean * unit,
            self.p50 * unit,
            self.p90 * unit,
            self.p99 * unit,
            self.max * unit,
            s = suffix
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_matches_numpy() {
        // numpy: percentile([1,2,3,4], 25) == 1.75; percentile(..., 75) == 3.25
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
        assert!((percentile(&xs, 75.0) - 3.25).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[5.0], 37.0), 5.0);
    }

    #[test]
    fn iqr_threshold() {
        // numpy: q1 of 1..=8 is 2.75, q3 is 6.25, IQR 3.5; thr(1.5) = 11.5
        let xs: Vec<f64> = (1..=8).map(|x| x as f64).collect();
        let iqr = Iqr::of(&xs);
        assert!((iqr.q1 - 2.75).abs() < 1e-12);
        assert!((iqr.q3 - 6.25).abs() < 1e-12);
        assert!((iqr.outlier_threshold(1.5) - 11.5).abs() < 1e-12);
    }

    #[test]
    fn summary_orders() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.count, 3);
    }

    #[test]
    fn stddev_known() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sample_stddev_bessel_corrected() {
        // Population σ of {1,2,3} is √(2/3); sample s is 1 exactly.
        assert!((sample_stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(sample_stddev(&[5.0]), 0.0);
        assert_eq!(sample_stddev(&[]), 0.0);
    }
}
