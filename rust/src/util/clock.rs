//! Clock abstraction shared by the real serving path and the simulator.
//!
//! All timestamps in the crate are `f64` seconds from an arbitrary epoch.
//! Scheduler state machines never read a clock directly — they take
//! explicit `now` arguments — but the threaded real mode and the server
//! frontend need a time source, and tests need a controllable one.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonic time source in seconds.
pub trait Clock: Send + Sync {
    /// Seconds since this clock's epoch.
    fn now_s(&self) -> f64;
}

/// Wall-clock time from a process-local epoch.
#[derive(Debug)]
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    /// A clock whose epoch is "now".
    pub fn new() -> Self {
        RealClock {
            epoch: Instant::now(),
        }
    }

    /// The instant this clock counts from. Sharing an epoch across
    /// components (e.g. the remote-shard heartbeat pinger) keeps every
    /// `now_s` reading on one timebase, which the cross-process trace
    /// alignment depends on.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

/// A manually-advanced clock for tests and deterministic replay. Stores
/// nanoseconds in an atomic so it is `Sync` without locks.
#[derive(Debug, Default)]
pub struct ManualClock {
    nanos: AtomicU64,
}

impl ManualClock {
    /// A manual clock at t = 0.
    pub fn new() -> Self {
        ManualClock {
            nanos: AtomicU64::new(0),
        }
    }

    /// Advance by `dt` seconds.
    pub fn advance(&self, dt: f64) {
        assert!(dt >= 0.0, "clock cannot go backwards");
        self.nanos
            .fetch_add((dt * 1e9).round() as u64, Ordering::SeqCst);
    }

    /// Jump to an absolute time (must not go backwards).
    pub fn set(&self, t: f64) {
        let new = (t * 1e9).round() as u64;
        let old = self.nanos.load(Ordering::SeqCst);
        assert!(new >= old, "clock cannot go backwards");
        self.nanos.store(new, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_s(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now_s();
        let b = c.now_s();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new();
        assert_eq!(c.now_s(), 0.0);
        c.advance(1.5);
        assert!((c.now_s() - 1.5).abs() < 1e-9);
        c.set(3.0);
        assert!((c.now_s() - 3.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn manual_clock_rejects_backwards() {
        let c = ManualClock::new();
        c.set(2.0);
        c.set(1.0);
    }
}
