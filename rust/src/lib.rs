//! # SBS — Staggered Batch Scheduling for P/D-disaggregated DP+EP LLM serving
//!
//! Reproduction of *"Staggered Batch Scheduling: Co-optimizing
//! Time-to-First-Token and Throughput for High-Efficiency LLM Inference"*
//! (Tian et al., Baidu, CS.DC 2025).
//!
//! The crate is organised in three planes mirroring the paper's Figure 5:
//!
//! * **Control plane** — [`scheduler`]: the staggered batch main loop
//!   ([`scheduler::staggered`]), the throughput-adaptive interval controller
//!   (Algorithm 1, [`scheduler::interval`]), the Prioritized Batch
//!   Allocation Algorithm for prefill (Algorithm 2, [`scheduler::pbaa`]),
//!   and the IQR-aware lexicographical decode scheduler (Algorithm 3,
//!   [`scheduler::decode`]). Immediate-dispatch baselines live in
//!   [`scheduler::baseline`].
//! * **State plane** — [`scheduler::state`] (the global state matrix
//!   `⟨C_avail, B_i, K_i⟩`) and [`scheduler::sync`] (the multi-tier state
//!   synchronization protocol: quiescence polling, `EndForward` fast path,
//!   liveness watchdog).
//! * **Resource plane** — [`cluster`]: a discrete-event simulation of
//!   gated, non-preemptive DP+EP inference instances (used for the paper's
//!   cluster-scale experiments) and a threaded *real* mode in which each
//!   instance executes actual forward passes through the PJRT runtime
//!   ([`runtime`], [`engine`]).
//!
//! Python/JAX/Pallas participate only at build time: `make artifacts`
//! lowers the nano-MoE model (L2) and its Pallas kernels (L1) to HLO text,
//! which [`runtime`] loads through the `xla` crate's PJRT CPU client. The
//! request path is pure Rust.
//!
//! ## Quick start
//!
//! ```no_run
//! use sbs::config::SimConfig;
//! use sbs::cluster::sim::Simulation;
//!
//! let cfg = SimConfig::paper_fig6a(0.8); // 80% of baseline peak load
//! let report = Simulation::run(&cfg);
//! println!("mean TTFT = {:.1} ms", report.report.ttft.mean_ms());
//! ```

pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod engine;
pub mod figures;
pub mod json;
pub mod logging;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod testing;
pub mod trace;
pub mod transport;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
