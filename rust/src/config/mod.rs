//! Typed experiment/serving configuration and the paper presets.
//!
//! Re-exports the simulation config types and provides the named presets
//! used by the figures harness, benches and examples, plus a small
//! `key=value` config-file loader for the `sbs` CLI.

pub use crate::cluster::sim::{DecodePlacement, SchedMode, SimConfig, SimTopology};

use crate::cluster::costmodel::{DecodeCostModel, KvTransferModel, PrefillCostModel};
use crate::cluster::dispatch::RescueConfig;
use crate::scheduler::baseline::ImmediatePolicy;
use crate::scheduler::decode::DecodeSchedConfig;
use crate::scheduler::staggered::StaggeredConfig;
use crate::workload::WorkloadSpec;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Baseline peak QPS for the Fig. 6(a) topology (3P1D, chunk 3K, short
/// inputs): the highest rate at which the immediate-dispatch baseline
/// still meets the 0.8 s mean-TTFT SLO, determined by the Table 1 search
/// with the default cost model. Load levels in Fig. 6 are fractions of
/// this (the paper's protocol, §5.1).
pub const FIG6A_BASELINE_PEAK_QPS: f64 = 150.0;

/// Baseline peak QPS for the Fig. 6(b) long-context topology (chunk 16K,
/// mean input ≈ 6.7K tokens), same protocol at a ~6 s mean-TTFT SLO (multi-chunk prefills make sub-second TTFT unattainable at 64K context).
pub const FIG6B_BASELINE_PEAK_QPS: f64 = 12.0;

/// Default per-DP-unit KV-token budget on the *live* decode path,
/// mirroring the DES's `DecodeCaps::kv_max` so the simulated and live
/// admissibility checks share one number: a decode join reserves its
/// expected resident length (`prompt + max_new`) against this budget and
/// parks when no unit has room (byte-accurate backpressure instead of
/// slot counting alone).
pub const LIVE_KV_BUDGET_TOKENS: u64 = 150_000;

/// String form of [`LIVE_KV_BUDGET_TOKENS`] for CLI help text (the CLI
/// substrate wants `&'static str` defaults); a test asserts the two
/// cannot drift.
pub const LIVE_KV_BUDGET_TOKENS_STR: &str = "150000";

/// Elements (f32) per `KvSegment` frame in the prefill→decode KV
/// handoff: 512 Ki elements = 2 MiB of payload per chunk. Small enough
/// that other units' tokens and terminals interleave between a long
/// prompt's segments on the shard connection, large enough that framing
/// overhead stays negligible against PJRT-scale caches.
pub const KV_SEGMENT_ELEMS: usize = 512 * 1024;

/// Simulation horizon used by the figure harness (virtual seconds).
pub const FIG_HORIZON_S: f64 = 180.0;

/// Warmup cut for figure metrics (virtual seconds).
pub const FIG_WARMUP_S: f64 = 30.0;

/// Fig. 6(a) preset: short inputs (0–3K, mean 1K), chunk 3K, 3P1D.
pub fn fig6a(load: f64, staggered: bool, seed: u64) -> SimConfig {
    let qps = FIG6A_BASELINE_PEAK_QPS * load;
    let mut cfg = SimConfig {
        topology: SimTopology::paper_3p1d(3072),
        workload: WorkloadSpec::paper_short(qps, FIG_HORIZON_S, seed),
        mode: SchedMode::Staggered(StaggeredConfig::default()),
        decode: DecodePlacement::IqrLex(DecodeSchedConfig::default()),
        prefill_cost: PrefillCostModel::default(),
        decode_cost: DecodeCostModel::default(),
        kv_transfer: KvTransferModel::default(),
        l_net: 0.002,
        formation_delay: 0.004,
        warmup: FIG_WARMUP_S,
        kv_sample_interval: 0.0,
        max_time: 1.0e4,
        fault_lose_endforward: 0.0,
        decode_caps: crate::cluster::decode::DecodeCaps::default(),
        rescue: RescueConfig::default(),
    };
    if !staggered {
        cfg.mode = SchedMode::Immediate(ImmediatePolicy::LeastOutstanding);
    }
    cfg
}

/// Fig. 6(b) preset: long context (3K–64K, mean 6.7K), chunk 16K.
pub fn fig6b(load: f64, staggered: bool, seed: u64) -> SimConfig {
    let qps = FIG6B_BASELINE_PEAK_QPS * load;
    let mut cfg = fig6a(1.0, staggered, seed);
    cfg.topology = SimTopology::paper_3p1d(16384);
    cfg.workload = WorkloadSpec::paper_long(qps, FIG_HORIZON_S, seed);
    cfg
}

/// Table 1 preset: given chunk size, scheduler mode and QPS.
pub fn table1(c_chunk: u32, qps: f64, staggered: bool, seed: u64) -> SimConfig {
    let mut cfg = fig6a(1.0, staggered, seed);
    cfg.topology = SimTopology::paper_3p1d(c_chunk);
    cfg.workload = WorkloadSpec::paper_short(qps, FIG_HORIZON_S, seed);
    cfg
}

/// Fig. 7/8 preset: decode-heavy workload on DP=32 decode, generous
/// prefill pool (decode is the subject), IQR vs round-robin placement.
pub fn fig7(qps: f64, iqr: bool, seed: u64) -> SimConfig {
    let mut cfg = fig6a(1.0, true, seed);
    cfg.topology = SimTopology {
        n_prefill: 8,
        dp_prefill: 8,
        c_chunk: 3072,
        n_decode: 1,
        dp_decode: 32,
    };
    // Decode experiments need steady state (a request lives ~25–30 s), so
    // run a longer horizon than the TTFT figures.
    cfg.workload = WorkloadSpec::paper_decode(qps, 2.0 * FIG_HORIZON_S, seed);
    cfg.warmup = 60.0; // past the concurrency ramp
    cfg.decode = if iqr {
        DecodePlacement::IqrLex(DecodeSchedConfig::default())
    } else {
        DecodePlacement::Random
    };
    cfg.kv_sample_interval = 1.0;
    cfg
}

/// Fig. 8 preset: decode *service-rate* measurement — slot-bound regime
/// (b_max = 35, the paper's operating batch size; KV cap non-binding) at
/// an offered load that keeps every slot full, so step-time inflation
/// from KV imbalance is the only variable.
pub fn fig8(qps: f64, iqr: bool, seed: u64) -> SimConfig {
    let mut cfg = fig7(qps, iqr, seed);
    cfg.decode_caps = crate::cluster::decode::DecodeCaps {
        b_max: 35,
        kv_max: 400_000,
    };
    cfg.kv_sample_interval = 0.0;
    cfg
}

/// A minimal `key = value` config file (`#` comments). Used by
/// `sbs simulate --config`; keys override preset fields.
#[derive(Debug, Clone, Default)]
pub struct KvFile {
    map: BTreeMap<String, String>,
}

impl KvFile {
    /// Parse a config file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse config text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", no + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(KvFile { map })
    }

    /// Raw value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    /// Parsed value with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("config key '{key}': bad value '{s}'")),
        }
    }

    /// Apply known keys onto a [`SimConfig`].
    pub fn apply(&self, cfg: &mut SimConfig) -> Result<()> {
        cfg.topology.n_prefill = self.get_or("n_prefill", cfg.topology.n_prefill)?;
        cfg.topology.dp_prefill = self.get_or("dp_prefill", cfg.topology.dp_prefill)?;
        cfg.topology.c_chunk = self.get_or("c_chunk", cfg.topology.c_chunk)?;
        cfg.topology.n_decode = self.get_or("n_decode", cfg.topology.n_decode)?;
        cfg.topology.dp_decode = self.get_or("dp_decode", cfg.topology.dp_decode)?;
        cfg.l_net = self.get_or("l_net", cfg.l_net)?;
        cfg.warmup = self.get_or("warmup", cfg.warmup)?;
        cfg.kv_sample_interval = self.get_or("kv_sample_interval", cfg.kv_sample_interval)?;
        if let Some(mode) = self.get("scheduler") {
            cfg.mode = match mode {
                "staggered" | "sbs" => SchedMode::Staggered(StaggeredConfig::default()),
                "round_robin" => SchedMode::Immediate(ImmediatePolicy::RoundRobin),
                "least_outstanding" => SchedMode::Immediate(ImmediatePolicy::LeastOutstanding),
                "jsq" => SchedMode::Immediate(ImmediatePolicy::JoinShortestQueue),
                other => return Err(anyhow!("unknown scheduler '{other}'")),
            };
        }
        if let Some(d) = self.get("decode_placement") {
            cfg.decode = match d {
                "iqr" | "load_aware" => DecodePlacement::IqrLex(DecodeSchedConfig::default()),
                "deadline_aware" => DecodePlacement::DeadlineAware(DecodeSchedConfig::default()),
                "round_robin" => DecodePlacement::RoundRobin,
                "random" => DecodePlacement::Random,
                other => return Err(anyhow!("unknown decode_placement '{other}'")),
            };
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_budget_help_string_matches_constant() {
        assert_eq!(
            LIVE_KV_BUDGET_TOKENS_STR.parse::<u64>().unwrap(),
            LIVE_KV_BUDGET_TOKENS
        );
    }

    #[test]
    fn presets_construct() {
        let c = fig6a(0.8, true, 1);
        assert!(matches!(c.mode, SchedMode::Staggered(_)));
        let c = fig6a(0.8, false, 1);
        assert!(matches!(c.mode, SchedMode::Immediate(_)));
        let c = fig6b(0.6, true, 1);
        assert_eq!(c.topology.c_chunk, 16384);
        let c = fig7(40.0, false, 1);
        assert!(matches!(c.decode, DecodePlacement::Random));
        assert_eq!(c.topology.dp_decode, 32);
        assert!(c.kv_sample_interval > 0.0);
    }

    #[test]
    fn kvfile_parse_and_apply() {
        let kv = KvFile::parse("n_prefill = 5 # comment\nscheduler = jsq\n\nc_chunk=5120\n").unwrap();
        let mut cfg = fig6a(1.0, true, 1);
        kv.apply(&mut cfg).unwrap();
        assert_eq!(cfg.topology.n_prefill, 5);
        assert_eq!(cfg.topology.c_chunk, 5120);
        assert!(matches!(
            cfg.mode,
            SchedMode::Immediate(ImmediatePolicy::JoinShortestQueue)
        ));
    }

    #[test]
    fn kvfile_rejects_garbage() {
        assert!(KvFile::parse("no equals sign").is_err());
        let kv = KvFile::parse("n_prefill = abc").unwrap();
        let mut cfg = fig6a(1.0, true, 1);
        assert!(kv.apply(&mut cfg).is_err());
    }
}
