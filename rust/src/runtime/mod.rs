//! PJRT runtime: load the AOT artifacts (`make artifacts`) and execute the
//! nano-MoE model from Rust. Python never runs on this path.
//!
//! Artifact contract (see python/compile/aot.py):
//!
//! * `model_meta.json` — model config, parameter manifest, variant ABI.
//! * `weights.bin` — all parameters as little-endian f32 in manifest
//!   order. Uploaded once per client into device-resident buffers.
//! * `prefill_c{chunk}.hlo.txt` / `decode_b{batch}.hlo.txt` — HLO text
//!   entries: `(params..., tokens, k_caches, v_caches, pos|lens) ->
//!   (logits, k_caches, v_caches)` as a 3-tuple.
//!
//! Weights are uploaded once (`execute_b` with persistent `PjRtBuffer`s);
//! per-call operands (tokens + caches) are uploaded per call and the tuple
//! output is synced back to host literals — on the CPU PJRT plugin these
//! are memcpys, not PCIe transfers.

pub mod backend;
mod meta;

pub use meta::{ModelDims, ModelMeta, ParamMeta, VariantMeta};

use crate::cli::Command;
use anyhow::{anyhow, bail, Context, Result};
use backend::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Output of one model call.
pub struct StepOutput {
    /// Logits, flattened (`[chunk, vocab]` for prefill, `[batch, vocab]`
    /// for decode).
    pub logits: Vec<f32>,
    /// Updated K caches (host literal, ready to feed back).
    pub k_caches: Literal,
    /// Updated V caches.
    pub v_caches: Literal,
    /// Wall time of the PJRT execute + output sync, seconds.
    pub exec_time: f64,
    /// Vocab size (row stride of `logits`).
    pub vocab: usize,
}

impl StepOutput {
    /// Logits row for position/slot `idx`.
    pub fn logits_at(&self, idx: usize) -> Vec<f32> {
        self.logits[idx * self.vocab..(idx + 1) * self.vocab].to_vec()
    }
}

/// The loaded model runtime: one PJRT client, device-resident weights,
/// and one compiled executable per AOT variant. `Send + Sync`: workers
/// share it behind an `Arc`.
pub struct Runtime {
    client: PjRtClient,
    /// Parsed artifact metadata.
    pub meta: ModelMeta,
    param_bufs: Vec<PjRtBuffer>,
    prefill: HashMap<u32, PjRtLoadedExecutable>,
    decode: HashMap<u32, PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load artifacts from `dir`, compile all variants, upload weights.
    pub fn load(dir: &Path) -> Result<Runtime> {
        Self::load_filtered(dir, None)
    }

    /// Load artifacts, compiling only variants whose kind is in `kinds`
    /// (`None` = all). Workers that only prefill (or only decode) use this
    /// to halve startup compilation.
    pub fn load_filtered(dir: &Path, kinds: Option<&[&str]>) -> Result<Runtime> {
        let meta = ModelMeta::load(&dir.join("model_meta.json"))
            .context("loading model_meta.json — did you run `make artifacts`?")?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        log::info!(
            "PJRT platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );

        // Weights: one flat f32 blob, sliced per the manifest.
        let blob = std::fs::read(dir.join(&meta.weights_file))
            .with_context(|| format!("reading {}", meta.weights_file))?;
        if blob.len() != meta.total_f32 * 4 {
            bail!(
                "weights.bin size mismatch: {} bytes vs {} f32 expected",
                blob.len(),
                meta.total_f32
            );
        }
        let floats: Vec<f32> = blob
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut param_bufs = Vec::with_capacity(meta.params.len());
        for p in &meta.params {
            let n: usize = p.shape.iter().product::<usize>().max(1);
            let data = &floats[p.offset..p.offset + n];
            let dims: Vec<usize> = if p.shape.is_empty() {
                vec![1]
            } else {
                p.shape.clone()
            };
            let buf = client
                .buffer_from_host_buffer(data, &dims, None)
                .map_err(|e| anyhow!("uploading param {}: {e:?}", p.name))?;
            param_bufs.push(buf);
        }

        // Compile each variant from HLO text.
        let mut prefill = HashMap::new();
        let mut decode = HashMap::new();
        for v in &meta.variants {
            if let Some(kinds) = kinds {
                if !kinds.contains(&v.kind.as_str()) {
                    continue;
                }
            }
            let path = dir.join(&v.file);
            let t0 = Instant::now();
            let proto = backend::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", v.file))?;
            let comp = backend::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", v.file))?;
            log::info!("compiled {} in {:.2}s", v.name, t0.elapsed().as_secs_f64());
            match v.kind.as_str() {
                "prefill" => {
                    prefill.insert(v.chunk_or_batch, exe);
                }
                "decode" => {
                    decode.insert(v.chunk_or_batch, exe);
                }
                other => bail!("unknown variant kind '{other}'"),
            }
        }
        Ok(Runtime {
            client,
            meta,
            param_bufs,
            prefill,
            decode,
        })
    }

    /// Available prefill chunk sizes (sorted ascending).
    pub fn prefill_chunks(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.prefill.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Available decode batch sizes (sorted ascending).
    pub fn decode_batches(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.decode.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Zeroed single-sequence prefill cache literal `[L, S, H, Dh]`.
    pub fn empty_prefill_cache(&self) -> Literal {
        let m = &self.meta.model;
        let n = m.n_layers * m.max_seq * m.n_heads * m.d_head;
        Literal::vec1(&vec![0f32; n])
            .reshape(&[
                m.n_layers as i64,
                m.max_seq as i64,
                m.n_heads as i64,
                m.d_head as i64,
            ])
            .expect("reshape")
    }

    /// Zeroed batched decode cache literal `[L, B, S, H, Dh]`.
    pub fn empty_decode_cache(&self, batch: u32) -> Literal {
        let m = &self.meta.model;
        let n = m.n_layers * batch as usize * m.max_seq * m.n_heads * m.d_head;
        Literal::vec1(&vec![0f32; n])
            .reshape(&[
                m.n_layers as i64,
                batch as i64,
                m.max_seq as i64,
                m.n_heads as i64,
                m.d_head as i64,
            ])
            .expect("reshape")
    }

    fn run(
        &self,
        exe: &PjRtLoadedExecutable,
        operands: &[&Literal],
    ) -> Result<(Vec<f32>, Literal, Literal, f64)> {
        // Upload per-call operands; params are already device-resident.
        let uploaded: Vec<PjRtBuffer> = operands
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("uploading operand: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let mut args: Vec<&PjRtBuffer> = self.param_bufs.iter().collect();
        args.extend(uploaded.iter());
        let t0 = Instant::now();
        let outs = exe.execute_b(&args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("output sync: {e:?}"))?;
        let exec_time = t0.elapsed().as_secs_f64();
        let mut parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != 3 {
            bail!("expected 3 outputs, got {}", parts.len());
        }
        let vc = parts.pop().unwrap();
        let kc = parts.pop().unwrap();
        let logits = parts
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok((logits, kc, vc, exec_time))
    }

    /// Execute one prefill chunk: `tokens.len()` must equal a compiled
    /// chunk size; `pos` is the absolute position of `tokens[0]`.
    pub fn prefill_chunk(
        &self,
        tokens: &[i32],
        k_caches: &Literal,
        v_caches: &Literal,
        pos: i32,
    ) -> Result<StepOutput> {
        let chunk = tokens.len() as u32;
        let exe = self
            .prefill
            .get(&chunk)
            .ok_or_else(|| anyhow!("no prefill variant for chunk={chunk}"))?;
        let toks = Literal::vec1(tokens);
        let pos_l = Literal::scalar(pos);
        let (logits, kc, vc, exec_time) = self.run(exe, &[&toks, k_caches, v_caches, &pos_l])?;
        Ok(StepOutput {
            logits,
            k_caches: kc,
            v_caches: vc,
            exec_time,
            vocab: self.meta.model.vocab,
        })
    }

    /// Execute one decode step for a full batch: `tokens`/`lens` length
    /// must equal a compiled batch size. Inactive slots pass any token
    /// with `lens` pointing at a scratch row.
    pub fn decode_step(
        &self,
        tokens: &[i32],
        k_caches: &Literal,
        v_caches: &Literal,
        lens: &[i32],
    ) -> Result<StepOutput> {
        let batch = tokens.len() as u32;
        if lens.len() != tokens.len() {
            bail!("lens/tokens length mismatch");
        }
        let exe = self
            .decode
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode variant for batch={batch}"))?;
        let toks = Literal::vec1(tokens);
        let lens_l = Literal::vec1(lens);
        let (logits, kc, vc, exec_time) = self.run(exe, &[&toks, k_caches, v_caches, &lens_l])?;
        Ok(StepOutput {
            logits,
            k_caches: kc,
            v_caches: vc,
            exec_time,
            vocab: self.meta.model.vocab,
        })
    }
}

/// Duplicate a literal (the crate's `Literal` is not `Clone`): CPU
/// memcpy round-trip through the raw f32 data.
pub fn clone_literal(l: &Literal) -> Result<Literal> {
    let shape = l.array_shape().map_err(|e| anyhow!("{e:?}"))?;
    let data = l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
    let dims: Vec<i64> = shape.dims().to_vec();
    Literal::vec1(&data)
        .reshape(&dims)
        .map_err(|e| anyhow!("{e:?}"))
}

/// Default artifact directory (env `SBS_ARTIFACTS` overrides).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SBS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// `sbs calibrate`: measure real pass/step times and print cost-model
/// constants for the simulator (DESIGN.md §Hardware-Adaptation).
pub fn cli_calibrate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sbs calibrate", "measure PJRT execution times")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("iters", "timed iterations per variant", Some("5"));
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let iters: usize = args.parse_or("iters", 5).map_err(|e| anyhow!("{e}"))?;
    let rt = Runtime::load(&dir)?;

    println!("variant          mean_exec_s   tokens/s");
    let mut prefill_full = 0.0;
    let mut chunk_max = 0;
    for chunk in rt.prefill_chunks() {
        let tokens: Vec<i32> = (0..chunk as i32).map(|i| i % 500).collect();
        let kc = rt.empty_prefill_cache();
        let vc = rt.empty_prefill_cache();
        let _ = rt.prefill_chunk(&tokens, &kc, &vc, 0)?; // warmup
        let mut total = 0.0;
        for _ in 0..iters {
            total += rt.prefill_chunk(&tokens, &kc, &vc, 0)?.exec_time;
        }
        let mean = total / iters as f64;
        println!(
            "prefill_c{:<6} {:>12.4} {:>10.0}",
            chunk,
            mean,
            chunk as f64 / mean
        );
        if chunk > chunk_max {
            chunk_max = chunk;
            prefill_full = mean;
        }
    }
    for batch in rt.decode_batches() {
        let tokens: Vec<i32> = vec![7; batch as usize];
        let lens: Vec<i32> = vec![64; batch as usize];
        let kc = rt.empty_decode_cache(batch);
        let vc = rt.empty_decode_cache(batch);
        let _ = rt.decode_step(&tokens, &kc, &vc, &lens)?; // warmup
        let mut total = 0.0;
        for _ in 0..iters {
            total += rt.decode_step(&tokens, &kc, &vc, &lens)?.exec_time;
        }
        let mean = total / iters as f64;
        println!(
            "decode_b{:<7} {:>12.4} {:>10.0}",
            batch,
            mean,
            batch as f64 / mean
        );
    }
    if prefill_full > 0.0 {
        let model = crate::cluster::costmodel::PrefillCostModel::calibrated(
            chunk_max,
            chunk_max as f64 / 2.0,
            prefill_full,
        );
        println!(
            "\ncalibrated PrefillCostModel: t_sync={:.4} s_token={:.3e} s_attn={:.3e}",
            model.t_sync, model.s_token, model.s_attn
        );
    }
    Ok(())
}
