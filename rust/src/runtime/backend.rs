//! Compile-time switch between the real `xla` PJRT bindings and an inert
//! stub, so the crate builds (and the whole scheduler/serving stack runs,
//! via the mock engine) in environments whose registry lacks the `xla`
//! crate.
//!
//! With the `pjrt` feature enabled this module re-exports the `xla` types
//! verbatim; without it, the same names resolve to stubs whose
//! constructors fail with a descriptive error. [`super::Runtime::load`]
//! hits [`PjRtClient::cpu`] first, so no stubbed data path is ever
//! reachable: callers get `Err("built without the `pjrt` feature")` at
//! load time instead of a link error at build time.

#[cfg(feature = "pjrt")]
pub use xla::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation,
};

#[cfg(not(feature = "pjrt"))]
pub use stub::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation,
};

#[cfg(not(feature = "pjrt"))]
mod stub {
    //! API-compatible stand-ins for the subset of the `xla` crate the
    //! runtime and engine use. Every data-path method returns
    //! [`unsupported`]; only type-checking matters, because no value of
    //! these types can reach a data path (client construction fails).

    /// Stub error; rendered through `Debug` like the real crate's error.
    #[derive(Debug)]
    pub struct Error {
        msg: String,
    }

    fn unsupported<T>() -> Result<T, Error> {
        Err(Error {
            msg: "sbs was built without the `pjrt` feature (the `xla` crate \
                  is not available); use the mock engine or rebuild with \
                  --features pjrt after adding the xla dependency"
                .to_string(),
        })
    }

    /// Element types accepted by the stub literal constructors.
    pub trait Element: Copy {}
    impl Element for f32 {}
    impl Element for i32 {}

    /// Host tensor stand-in.
    pub struct Literal;

    /// Array shape stand-in (only `dims()` is used).
    pub struct ArrayShape;

    impl ArrayShape {
        /// Dimension sizes.
        pub fn dims(&self) -> Vec<i64> {
            Vec::new()
        }
    }

    impl Literal {
        /// Rank-1 literal from host data.
        pub fn vec1<T: Element>(_data: &[T]) -> Literal {
            Literal
        }

        /// Rank-0 literal.
        pub fn scalar<T: Element>(_x: T) -> Literal {
            Literal
        }

        /// Reshape (stub: shape is never materialized).
        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Ok(Literal)
        }

        /// Copy out as host values.
        pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
            unsupported()
        }

        /// Destructure a tuple literal.
        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            unsupported()
        }

        /// Shape of an array literal.
        pub fn array_shape(&self) -> Result<ArrayShape, Error> {
            unsupported()
        }
    }

    /// Device buffer stand-in.
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        /// Synchronous device→host copy.
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            unsupported()
        }
    }

    /// PJRT client stand-in: construction always fails, which is the
    /// single gate keeping every other stub method unreachable.
    pub struct PjRtClient;

    impl PjRtClient {
        /// CPU client (always fails without the `pjrt` feature).
        pub fn cpu() -> Result<PjRtClient, Error> {
            unsupported()
        }

        /// Backend platform name.
        pub fn platform_name(&self) -> String {
            "stub".to_string()
        }

        /// Addressable device count.
        pub fn device_count(&self) -> usize {
            0
        }

        /// Upload raw host data.
        pub fn buffer_from_host_buffer<T: Element>(
            &self,
            _data: &[T],
            _dims: &[usize],
            _device: Option<usize>,
        ) -> Result<PjRtBuffer, Error> {
            unsupported()
        }

        /// Upload a literal.
        pub fn buffer_from_host_literal(
            &self,
            _device: Option<usize>,
            _literal: &Literal,
        ) -> Result<PjRtBuffer, Error> {
            unsupported()
        }

        /// Compile a computation.
        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            unsupported()
        }
    }

    /// Compiled executable stand-in.
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        /// Execute with borrowed buffer arguments.
        pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            unsupported()
        }
    }

    /// HLO module proto stand-in.
    pub struct HloModuleProto;

    impl HloModuleProto {
        /// Parse HLO text from a file.
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            unsupported()
        }
    }

    /// XLA computation stand-in.
    pub struct XlaComputation;

    impl XlaComputation {
        /// Wrap a parsed proto.
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }
}
