//! Parse `artifacts/model_meta.json` — the ABI between aot.py and Rust.

use crate::json::{parse, Json};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Model hyperparameters (mirror of python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelDims {
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Head width.
    pub d_head: usize,
    /// KV capacity per sequence.
    pub max_seq: usize,
}

/// One parameter tensor in weights.bin.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    /// Dotted parameter name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Offset into weights.bin, in f32 elements.
    pub offset: usize,
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct VariantMeta {
    /// Variant name, e.g. `prefill_c128`.
    pub name: String,
    /// `"prefill"` or `"decode"`.
    pub kind: String,
    /// Chunk size (prefill) or batch size (decode).
    pub chunk_or_batch: u32,
    /// HLO text file name.
    pub file: String,
}

/// Parsed artifact metadata.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Model dimensions.
    pub model: ModelDims,
    /// Weights blob file name.
    pub weights_file: String,
    /// Total f32 elements in the blob.
    pub total_f32: usize,
    /// Parameter manifest, in argument order.
    pub params: Vec<ParamMeta>,
    /// Entry-point variants.
    pub variants: Vec<VariantMeta>,
}

impl ModelMeta {
    /// Load and validate the metadata file.
    pub fn load(path: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse metadata from JSON text.
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let j = parse(text).map_err(|e| anyhow!("model_meta.json: {e}"))?;
        let num = |o: &Json, k: &str| -> Result<usize> {
            o.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing numeric field '{k}'"))
        };
        let model_j = j.get("model").ok_or_else(|| anyhow!("missing 'model'"))?;
        let model = ModelDims {
            vocab: num(model_j, "vocab")?,
            d_model: num(model_j, "d_model")?,
            n_layers: num(model_j, "n_layers")?,
            n_heads: num(model_j, "n_heads")?,
            d_head: num(model_j, "d_head")?,
            max_seq: num(model_j, "max_seq")?,
        };
        let w = j.get("weights").ok_or_else(|| anyhow!("missing 'weights'"))?;
        let weights_file = w
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing weights.file"))?
            .to_string();
        let total_f32 = num(w, "total_f32")?;
        let mut params = Vec::new();
        for p in w
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing weights.params"))?
        {
            let shape = p
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("param missing shape"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape")))
                .collect::<Result<Vec<_>>>()?;
            params.push(ParamMeta {
                name: p
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("param missing name"))?
                    .to_string(),
                shape,
                offset: num(p, "offset")?,
            });
        }
        let mut variants = Vec::new();
        for v in j
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing 'variants'"))?
        {
            let kind = v
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("variant missing kind"))?
                .to_string();
            let cb = match kind.as_str() {
                "prefill" => num(v, "chunk")?,
                "decode" => num(v, "batch")?,
                other => return Err(anyhow!("unknown variant kind '{other}'")),
            } as u32;
            variants.push(VariantMeta {
                name: v
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("variant missing name"))?
                    .to_string(),
                kind,
                chunk_or_batch: cb,
                file: v
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("variant missing file"))?
                    .to_string(),
            });
        }
        // Sanity: manifest offsets are monotone and end at total_f32.
        let mut expected = 0usize;
        for p in &params {
            if p.offset != expected {
                return Err(anyhow!(
                    "param '{}' offset {} != expected {expected}",
                    p.name,
                    p.offset
                ));
            }
            expected += p.shape.iter().product::<usize>().max(1);
        }
        if expected != total_f32 {
            return Err(anyhow!("manifest covers {expected} f32 but total is {total_f32}"));
        }
        Ok(ModelMeta {
            model,
            weights_file,
            total_f32,
            params,
            variants,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"vocab": 512, "d_model": 8, "n_layers": 1, "n_heads": 2,
                "d_head": 4, "n_experts": 2, "top_k": 1, "d_ff": 8,
                "d_shared_ff": 8, "max_seq": 16},
      "weights": {"file": "weights.bin", "total_f32": 4104,
        "params": [
          {"name": "embed", "shape": [512, 8], "offset": 0},
          {"name": "norm_out", "shape": [8], "offset": 4096}
        ]},
      "variants": [
        {"name": "prefill_c64", "kind": "prefill", "chunk": 64, "file": "prefill_c64.hlo.txt"},
        {"name": "decode_b1", "kind": "decode", "batch": 1, "file": "decode_b1.hlo.txt"}
      ],
      "abi": {}, "seed": 0
    }"#;

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.variants.len(), 2);
        assert_eq!(m.variants[0].chunk_or_batch, 64);
        assert_eq!(m.variants[1].kind, "decode");
    }

    #[test]
    fn rejects_bad_offsets() {
        let bad = SAMPLE.replace("\"offset\": 4096", "\"offset\": 4000");
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(ModelMeta::parse("{}").is_err());
    }
}
