//! Paper Figure 6(b): TTFT vs load for long-context inputs (3K–64K, mean
//! 6.7K), chunk 16K. Validates SBS tail-latency suppression under high
//! length variance.
//!
//! Run: `cargo bench --bench bench_fig6b_ttft_long`

use sbs::bench_harness::{default_bencher, section};
use sbs::cluster::sim::Simulation;
use sbs::{config, figures};

fn main() {
    section("Figure 6(b) — TTFT vs load (long context)");
    let _ = figures::run_fig6b(figures::FIG_SEED);

    section("simulation cost (one 80%-load run)");
    let b = default_bencher();
    let mut cfg = config::fig6b(0.8, true, 1);
    cfg.workload.duration = 40.0;
    cfg.warmup = 8.0;
    b.report("sim fig6b SBS 40s-horizon", || Simulation::run(&cfg).completed);
}
