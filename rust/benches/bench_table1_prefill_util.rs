//! Paper Table 1: prefill chunk utilization and max sustainable QPS under
//! a mean-TTFT SLO, batching Off (immediate dispatch) vs On (SBS with
//! PBAA water-filling).
//!
//! Run: `cargo bench --bench bench_table1_prefill_util`
//! The SLO bisection runs ~40 simulations; `SBS_FIG_QUICK=1` recommended
//! for iteration.

use sbs::bench_harness::section;
use sbs::figures;

fn main() {
    section("Table 1 — chunk utilization & max QPS under SLO");
    let _ = figures::run_table1(figures::FIG_SEED);
}
