//! Real-engine benchmarks: PJRT prefill-chunk and decode-step latencies
//! through the AOT artifacts (requires `make artifacts`; skips gracefully
//! otherwise).
//!
//! Run: `make artifacts && cargo bench --bench bench_runtime`

use sbs::bench_harness::{default_bencher, section, Bencher};
use sbs::runtime::{artifacts_dir, Runtime};
use std::time::Duration;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("model_meta.json").exists() {
        eprintln!(
            "bench_runtime: no artifacts at {} — run `make artifacts` first (skipping)",
            dir.display()
        );
        return;
    }
    let rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench_runtime: failed to load runtime: {e:#} (skipping)");
            return;
        }
    };
    // PJRT passes take ~0.2–1 s each; use small budgets.
    let b = Bencher {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(4),
        ..default_bencher()
    };

    section("prefill chunk passes (real PJRT execution)");
    for chunk in rt.prefill_chunks() {
        let tokens: Vec<i32> = (0..chunk as i32).map(|i| i % 500).collect();
        let kc = rt.empty_prefill_cache();
        let vc = rt.empty_prefill_cache();
        let r = b.report(&format!("prefill_c{chunk}"), || {
            rt.prefill_chunk(&tokens, &kc, &vc, 0).unwrap().exec_time
        });
        println!(
            "    → {:.0} prefill tokens/s",
            chunk as f64 * r.per_sec()
        );
    }

    section("decode steps (real PJRT execution)");
    for batch in rt.decode_batches() {
        let tokens = vec![7i32; batch as usize];
        let lens = vec![64i32; batch as usize];
        let kc = rt.empty_decode_cache(batch);
        let vc = rt.empty_decode_cache(batch);
        let r = b.report(&format!("decode_b{batch}"), || {
            rt.decode_step(&tokens, &kc, &vc, &lens).unwrap().exec_time
        });
        println!("    → {:.1} decode tokens/s", batch as f64 * r.per_sec());
    }
}
