//! Micro-benchmarks of the L3 scheduler hot paths: the costs that must
//! stay ≪ T̄_fwd/100 so the control plane never bottlenecks the cluster.
//!
//! Run: `cargo bench --bench bench_scheduler_micro`

use sbs::bench_harness::{default_bencher, section};
use sbs::scheduler::decode::{schedule_batch, DecodeSchedConfig};
use sbs::scheduler::interval::{IntervalConfig, IntervalController};
use sbs::scheduler::pbaa::{allocate, PbaaConfig};
use sbs::scheduler::prefix::{PrefixCacheModel, RadixTree};
use sbs::scheduler::staggered::{SchedulerEvent, StaggeredConfig, StaggeredScheduler};
use sbs::scheduler::state::DpState;
use sbs::scheduler::types::{DpUnitId, Request};
use sbs::util::stats::Iqr;
use sbs::util::Rng;

fn requests(n: usize, rng: &mut Rng) -> Vec<Request> {
    (0..n)
        .map(|i| {
            Request::new(
                i as u64,
                rng.range_u64(16, 3072) as u32,
                rng.range_u64(16, 512) as u32,
                0.0,
            )
        })
        .collect()
}

fn dp_pool(n: usize, c_chunk: u32) -> Vec<DpState> {
    (0..n)
        .map(|i| DpState::new(DpUnitId::new(0, i as u32), c_chunk))
        .collect()
}

fn main() {
    let b = default_bencher();
    let mut rng = Rng::new(42);

    section("PBAA (Algorithm 2) — one allocation cycle");
    for (n_req, n_dp) in [(16usize, 8usize), (64, 8), (256, 32)] {
        let reqs = requests(n_req, &mut rng);
        b.report(&format!("pbaa {n_req} reqs × {n_dp} DPs"), || {
            let mut dps = dp_pool(n_dp, 3072);
            allocate(&PbaaConfig::default(), vec![], reqs.clone(), &mut dps, None)
                .assignments
                .len()
        });
    }

    section("IQR-lex decode scheduling (Algorithm 3) — one batch");
    for (n_req, n_dp) in [(8usize, 32usize), (64, 32), (64, 128)] {
        let reqs = requests(n_req, &mut rng);
        b.report(&format!("alg3 {n_req} reqs × {n_dp} DPs"), || {
            let mut dps = dp_pool(n_dp, 0);
            schedule_batch(&DecodeSchedConfig::default(), reqs.clone(), &mut dps).len()
        });
    }

    section("IQR computation");
    let kvs: Vec<f64> = (0..32).map(|_| rng.uniform(0.0, 150_000.0)).collect();
    b.report("Iqr::of over 32 units", || Iqr::of(&kvs).outlier_threshold(1.5));

    section("interval controller (Algorithm 1)");
    let mut ctl = IntervalController::new(IntervalConfig::default(), 16);
    b.report("on_end_forward + recompute", || {
        ctl.on_end_forward(0.35);
        ctl.i_opt()
    });

    section("radix tree (cache-aware PBAA)");
    let mut tree = RadixTree::new(u64::MAX);
    let toks = PrefixCacheModel::group_tokens(7, 512);
    tree.insert(&toks);
    b.report("match_prefix 512 tokens (hit)", || tree.match_prefix(&toks));
    let miss = PrefixCacheModel::group_tokens(8, 512);
    b.report("match_prefix 512 tokens (miss)", || tree.match_prefix(&miss));

    section("full scheduler event (arrival → dispatch decision)");
    let mut sched = StaggeredScheduler::new(StaggeredConfig::default(), 3, 8, 3072);
    let mut t = 0.0;
    let mut id = 0u64;
    b.report("StaggeredScheduler::on_event(Arrival)", || {
        t += 0.01;
        id += 1;
        sched
            .on_event(SchedulerEvent::Arrival {
                request: Request::new(id, 1000, 100, t),
                now: t,
            })
            .len()
    });
}
