//! Paper Figure 7: decode KV-cache load dispersion across DP=32 units
//! over time — baseline (blind random routing) vs IQR-aware
//! lexicographical scheduling — plus the live counterpart: the same
//! comparison on the threaded mock-engine cluster with a 4-worker decode
//! DP pool, measured through the shared dispatch core's per-DP
//! occupancy/imbalance gauges.
//!
//! Run: `cargo bench --bench bench_fig7_decode_balance`

use sbs::bench_harness::section;
use sbs::cluster::dispatch::DecodePolicy;
use sbs::cluster::workers::RealCluster;
use sbs::figures;
use sbs::metrics::DecodePoolStats;
use sbs::testing::scenarios::{skewed_decode_cluster, submit_skewed_jobs};

/// Live decode-balance scenario: skewed output lengths (every 4th job is
/// 50× longer) over `n_decode = 4` mock decode workers — the same
/// configuration the `decode_balance` integration suite asserts on.
fn live_decode_balance(policy: DecodePolicy) -> anyhow::Result<DecodePoolStats> {
    let cluster = RealCluster::start(skewed_decode_cluster(policy, 4))?;
    let handle = cluster.handle();
    submit_skewed_jobs(&cluster, 40, 4, 150, 3);
    let _ = cluster.finish()?;
    Ok(handle.decode_stats())
}

fn main() {
    section("Figure 7 — decode KV load distribution");
    let _ = figures::run_fig7(figures::FIG_SEED);

    section("Live decode-balance (mock cluster, n_decode = 4, skewed outputs)");
    let policies = [
        DecodePolicy::LoadAware(Default::default()),
        DecodePolicy::RoundRobin,
        DecodePolicy::Random,
    ];
    for policy in policies {
        match live_decode_balance(policy) {
            Ok(stats) => println!(
                "{:>11}: busy-time imbalance {:.3} (max/mean over {} DP units, {} placements)",
                stats.policy,
                stats.imbalance(),
                stats.units.len(),
                stats.total_placed(),
            ),
            Err(e) => eprintln!("live scenario failed: {e:#}"),
        }
    }
}
