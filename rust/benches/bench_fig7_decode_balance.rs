//! Paper Figure 7: decode KV-cache load dispersion across DP=32 units
//! over time — baseline (blind random routing) vs IQR-aware
//! lexicographical scheduling.
//!
//! Run: `cargo bench --bench bench_fig7_decode_balance`

use sbs::bench_harness::section;
use sbs::figures;

fn main() {
    section("Figure 7 — decode KV load distribution");
    let _ = figures::run_fig7(figures::FIG_SEED);
}
