//! Paper Figure 6(a): mean TTFT vs load, short inputs (0–3K, mean 1K),
//! chunk 3K, 3P1D. Prints the paper-style series and times one sim run.
//!
//! Run: `cargo bench --bench bench_fig6a_ttft_short`
//! (`SBS_FIG_QUICK=1` shortens horizons ~6×.)

use sbs::bench_harness::{default_bencher, section};
use sbs::cluster::sim::Simulation;
use sbs::{config, figures};

fn main() {
    section("Figure 6(a) — TTFT vs load (short inputs)");
    let j = figures::run_fig6a(figures::FIG_SEED);
    let _ = j;

    section("simulation cost (one 80%-load run, both schedulers)");
    let b = default_bencher();
    let mut quick_cfg = config::fig6a(0.8, true, 1);
    quick_cfg.workload.duration = 30.0;
    quick_cfg.warmup = 5.0;
    b.report("sim fig6a SBS 30s-horizon", || {
        Simulation::run(&quick_cfg).completed
    });
    let mut base_cfg = config::fig6a(0.8, false, 1);
    base_cfg.workload.duration = 30.0;
    base_cfg.warmup = 5.0;
    b.report("sim fig6a baseline 30s-horizon", || {
        Simulation::run(&base_cfg).completed
    });
}
