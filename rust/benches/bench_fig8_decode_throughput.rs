//! Paper Figure 8: aggregate decode throughput (service rate) — baseline
//! vs IQR-aware placement under the EP sync barrier.
//!
//! Run: `cargo bench --bench bench_fig8_decode_throughput`

use sbs::bench_harness::section;
use sbs::figures;

fn main() {
    section("Figure 8 — decode throughput (service rate)");
    let _ = figures::run_fig8(figures::FIG_SEED);
}
