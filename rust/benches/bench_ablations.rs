//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. adaptive vs static dispatch interval (Algorithm 1 on/off);
//! 2. IQR multiplier `k` sweep + mask/pre-sort ablations (Algorithm 3);
//! 3. cache-aware vs basic PBAA on a shared-prefix workload;
//! 4. immediate-dispatch policy comparison (RR / least-outstanding / JSQ);
//! 5. watchdog fault injection (lost EndForward liveness).
//!
//! Run: `cargo bench --bench bench_ablations` (`SBS_FIG_QUICK=1` for speed)

use sbs::bench_harness::section;
use sbs::cluster::sim::{DecodePlacement, SchedMode, Simulation};
use sbs::config;
use sbs::scheduler::baseline::ImmediatePolicy;
use sbs::scheduler::decode::DecodeSchedConfig;
use sbs::scheduler::staggered::{
    SchedulerAction, SchedulerEvent, StaggeredConfig, StaggeredScheduler,
};
use sbs::scheduler::types::Request;
use sbs::workload::{LengthDist, PrefixSpec};

fn horizon() -> f64 {
    if std::env::var("SBS_FIG_QUICK").as_deref() == Ok("1") {
        40.0
    } else {
        120.0
    }
}

fn main() {
    let seed = 2025;

    section("A1 — adaptive vs static interval (fig6a @ 80% load)");
    for (label, adaptive) in [("adaptive (Alg 1)", true), ("static I_opt", false)] {
        let mut cfg = config::fig6a(0.8, true, seed);
        cfg.workload.duration = horizon();
        cfg.warmup = horizon() / 6.0;
        if let SchedMode::Staggered(sc) = &mut cfg.mode {
            sc.interval.adaptive = adaptive;
            // Static default deliberately miscalibrated 2× to show the
            // cost of not adapting.
            if !adaptive {
                sc.interval.t_default = 0.8;
            }
        }
        let r = Simulation::run(&cfg);
        println!(
            "  {label:<18} mean TTFT {:>8.1} ms   p99 {:>8.1} ms",
            r.report.ttft.mean_ms(),
            r.report.ttft.percentile_ms(99.0)
        );
    }

    section("A2 — Algorithm 3 knobs (fig7 workload)");
    let variants: Vec<(&str, DecodePlacement)> = vec![
        ("IQR k=1.5 (paper)", DecodePlacement::IqrLex(DecodeSchedConfig::default())),
        (
            "IQR k=0.5 (aggressive)",
            DecodePlacement::IqrLex(DecodeSchedConfig { iqr_k: 0.5, ..Default::default() }),
        ),
        (
            "IQR k=4.0 (lenient)",
            DecodePlacement::IqrLex(DecodeSchedConfig { iqr_k: 4.0, ..Default::default() }),
        ),
        (
            "no outlier mask",
            DecodePlacement::IqrLex(DecodeSchedConfig {
                mask_outliers: false,
                ..Default::default()
            }),
        ),
        (
            "no pre-sort",
            DecodePlacement::IqrLex(DecodeSchedConfig { pre_sort: false, ..Default::default() }),
        ),
        ("random (baseline)", DecodePlacement::Random),
        ("round-robin", DecodePlacement::RoundRobin),
    ];
    for (label, placement) in variants {
        let mut cfg = config::fig7(40.0, true, seed);
        cfg.workload.duration = horizon() * 2.0;
        cfg.warmup = horizon() / 2.0;
        cfg.decode = placement;
        let r = Simulation::run(&cfg);
        let (mean, std) = r.kv_band();
        let service = r.decode_tokens as f64 / r.decode_busy_s.max(1e-9);
        println!(
            "  {label:<24} KV mean {mean:>8.0} σ {std:>7.0}   service {service:>7.0} tok/s"
        );
    }

    section("A3 — cache-aware vs basic PBAA (shared-prefix workload)");
    for (label, cache_aware) in [("basic capacity", false), ("cache-aware", true)] {
        let mut cfg = config::fig6a(0.8, true, seed);
        cfg.workload.duration = horizon();
        cfg.warmup = horizon() / 6.0;
        cfg.workload.prefix = Some(PrefixSpec {
            groups: 16,
            zipf_s: 1.1,
            prefix_len: LengthDist::Uniform { lo: 256, hi: 1024 },
            participation: 0.8,
        });
        if let SchedMode::Staggered(sc) = &mut cfg.mode {
            sc.pbaa.cache_aware = cache_aware;
        }
        let r = Simulation::run(&cfg);
        println!(
            "  {label:<18} mean TTFT {:>8.1} ms   prefill_tps {:>8.0} (effective-token savings show as lower tps for equal service)",
            r.report.ttft.mean_ms(),
            r.report.throughput.prefill_tps(),
        );
    }

    section("A4 — immediate-dispatch policy comparison (fig6a @ 80%)");
    for policy in [
        ImmediatePolicy::RoundRobin,
        ImmediatePolicy::LeastOutstanding,
        ImmediatePolicy::JoinShortestQueue,
    ] {
        let mut cfg = config::fig6a(0.8, false, seed);
        cfg.workload.duration = horizon();
        cfg.warmup = horizon() / 6.0;
        cfg.mode = SchedMode::Immediate(policy);
        let r = Simulation::run(&cfg);
        println!(
            "  {policy:?}: mean TTFT {:>8.1} ms  device-queue {:>7.1} ms",
            r.report.ttft.mean_ms(),
            r.report.device_queue.mean_ms()
        );
    }

    section("A5 — watchdog fault injection (lost EndForward)");
    // Drive the scheduler state machine directly: dispatch, drop the
    // EndForward, and verify liveness via the watchdog path.
    let mut s = StaggeredScheduler::new(StaggeredConfig::default(), 2, 2, 3072);
    let mut resets = 0;
    let mut dispatches = 0;
    let mut t = 0.0;
    for i in 0..200u64 {
        t += 0.05;
        let acts = s.on_event(SchedulerEvent::Arrival {
            request: Request::new(i, 800, 64, t),
            now: t,
        });
        for a in &acts {
            match a {
                SchedulerAction::Dispatch(_) => dispatches += 1,
                SchedulerAction::Watchdog(_) => resets += 1,
                _ => {}
            }
        }
        // Simulate 50% EndForward loss: only even instances report.
        if i % 4 == 0 {
            let acts = s.on_event(SchedulerEvent::EndForward {
                instance: 0,
                t_measured: 0.3,
                remaining: Some(0),
                now: t,
            });
            dispatches += acts
                .iter()
                .filter(|a| matches!(a, SchedulerAction::Dispatch(_)))
                .count();
        }
        let acts = s.on_event(SchedulerEvent::Timer { now: t });
        for a in &acts {
            match a {
                SchedulerAction::Dispatch(_) => dispatches += 1,
                SchedulerAction::Watchdog(_) => resets += 1,
                _ => {}
            }
        }
    }
    println!(
        "  200 arrivals, instance 1 never signals: {dispatches} dispatches, {resets} watchdog events, degraded={}",
        s.degraded()
    );
    assert!(dispatches > 0 && resets > 0, "liveness must be maintained");
}
