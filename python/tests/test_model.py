"""L2 model correctness: kernelized forward vs reference forward, shape
contracts, KV-cache semantics, and chunked-prefill equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig
from compile import model

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(n_layers=2, max_seq=256)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


def caches():
    c = model.empty_prefill_cache(CFG)
    return c, jnp.zeros_like(c)


def test_param_spec_matches_init(params):
    spec = model.param_spec(CFG)
    assert len(spec) == len(params)
    for (name, shape), p in zip(spec, params):
        assert tuple(shape) == p.shape, name


def test_prefill_matches_reference(params):
    kc, vc = caches()
    tokens = jnp.arange(64, dtype=jnp.int32) % CFG.vocab
    lg, k1, v1 = model.prefill_chunk(CFG, params, tokens, kc, vc, jnp.int32(0))
    lr, k2, v2 = model.prefill_chunk_reference(CFG, params, tokens, kc, vc, jnp.int32(0))
    np.testing.assert_allclose(lg, lr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(k1, k2, rtol=1e-4, atol=1e-4)
    assert lg.shape == (64, CFG.vocab)
    assert k1.shape == (CFG.n_layers, CFG.max_seq, CFG.n_heads, CFG.d_head)


def test_decode_matches_reference(params):
    b = 4
    kc = model.empty_decode_cache(CFG, b)
    vc = jnp.zeros_like(kc)
    toks = jnp.array([1, 2, 3, 4], jnp.int32)
    lens = jnp.array([0, 3, 10, 100], jnp.int32)
    lg, k1, v1 = model.decode_step(CFG, params, toks, kc, vc, lens)
    lr, k2, v2 = model.decode_step_reference(CFG, params, toks, kc, vc, lens)
    np.testing.assert_allclose(lg, lr, rtol=1e-4, atol=1e-4)
    assert lg.shape == (b, CFG.vocab)


def test_chunked_prefill_equals_single_chunk(params):
    """Processing 128 tokens as 2×64 chunks must equal one 128 chunk."""
    tokens = (jnp.arange(128, dtype=jnp.int32) * 7 + 3) % CFG.vocab
    kc, vc = caches()
    lg_full, kf, vf = model.prefill_chunk(CFG, params, tokens, kc, vc, jnp.int32(0))
    kc2, vc2 = caches()
    _, kc2, vc2 = model.prefill_chunk(CFG, params, tokens[:64], kc2, vc2, jnp.int32(0))
    lg_2, k2, v2 = model.prefill_chunk(CFG, params, tokens[64:], kc2, vc2, jnp.int32(64))
    np.testing.assert_allclose(lg_full[-1], lg_2[-1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(lg_full[64:], lg_2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(kf[:, :128], k2[:, :128], rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_consistency(params):
    """Greedy decode after prefill equals teacher-forced prefill logits."""
    prompt = (jnp.arange(64, dtype=jnp.int32) * 3 + 1) % CFG.vocab
    kc, vc = caches()
    lg, kc, vc = model.prefill_chunk(CFG, params, prompt, kc, vc, jnp.int32(0))
    next_tok = jnp.argmax(lg[-1]).astype(jnp.int32)

    # Same continuation via a batched decode step (batch of 1).
    dk = model.empty_decode_cache(CFG, 1)
    dv = jnp.zeros_like(dk)
    dk = dk.at[:, 0].set(kc)
    dv = dv.at[:, 0].set(vc)
    lens = jnp.array([64], jnp.int32)
    lg_d, _, _ = model.decode_step(CFG, params, next_tok[None], dk, dv, lens)

    # Oracle: teacher-forced prefill over prompt + next token.
    kc3, vc3 = caches()
    full = jnp.concatenate([prompt, next_tok[None]])
    # chunk sizes must divide q_block; use reference for odd lengths.
    lg_tf, _, _ = model.prefill_chunk_reference(CFG, params, full, kc3, vc3, jnp.int32(0))
    np.testing.assert_allclose(lg_d[0], lg_tf[-1], rtol=5e-3, atol=5e-3)


def test_decode_updates_cache_at_lens(params):
    b = 2
    kc = model.empty_decode_cache(CFG, b)
    vc = jnp.zeros_like(kc)
    lens = jnp.array([5, 9], jnp.int32)
    toks = jnp.array([7, 11], jnp.int32)
    _, k1, _ = model.decode_step(CFG, params, toks, kc, vc, lens)
    # Rows at the write position are nonzero; rows beyond stay zero.
    assert float(jnp.abs(k1[:, 0, 5]).sum()) > 0
    assert float(jnp.abs(k1[:, 0, 6:]).sum()) == 0
    assert float(jnp.abs(k1[:, 1, 9]).sum()) > 0
    assert float(jnp.abs(k1[:, 1, 10:]).sum()) == 0


def test_param_count_sane():
    cfg = ModelConfig()
    n = sum(int(np.prod(s)) for _, s in model.param_spec(cfg))
    assert 4_000_000 < n < 20_000_000, n  # nano scale
