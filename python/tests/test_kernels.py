"""L1 kernel correctness: Pallas (interpret) vs pure-jnp references,
swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attn import decode_attention
from compile.kernels.flash_prefill import causal_prefill_attention, KV_BLOCK
from compile.kernels.moe_gemm import moe_expert_gemm

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- prefill

@settings(max_examples=20, deadline=None)
@given(
    heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([32, 64]),
    chunk_blocks=st.integers(1, 3),
    s_blocks=st.integers(1, 4),
    pos_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_attention_matches_ref(heads, dh, chunk_blocks, s_blocks, pos_frac, seed):
    q_block = 32
    chunk = q_block * chunk_blocks
    s = KV_BLOCK * s_blocks
    if chunk > s:
        chunk = q_block  # keep the chunk inside the cache
    max_pos = s - chunk
    pos = jnp.int32(int(pos_frac * max_pos))
    q = rand(seed, (chunk, heads, dh))
    k = rand(seed + 1, (s, heads, dh))
    v = rand(seed + 2, (s, heads, dh))
    out = causal_prefill_attention(q, k, v, pos, q_block=q_block)
    exp = ref.causal_prefill_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(out, exp, **TOL)


def test_prefill_attention_first_chunk_at_pos0():
    q = rand(0, (64, 2, 32))
    k = rand(1, (128, 2, 32))
    v = rand(2, (128, 2, 32))
    out = causal_prefill_attention(q, k, v, jnp.int32(0))
    exp = ref.causal_prefill_attention_ref(q, k, v, jnp.int32(0))
    np.testing.assert_allclose(out, exp, **TOL)
    # Token 0 attends only to itself: output == v[0].
    np.testing.assert_allclose(out[0], v[0], **TOL)


def test_prefill_attention_causality():
    """Perturbing future cache rows must not change outputs."""
    q = rand(0, (32, 2, 32))
    k = rand(1, (128, 2, 32))
    v = rand(2, (128, 2, 32))
    pos = jnp.int32(16)
    out1 = causal_prefill_attention(q, k, v, pos, q_block=32)
    k2 = k.at[64:].set(99.0)  # strictly after pos+chunk-1 = 47
    v2 = v.at[64:].set(-99.0)
    out2 = causal_prefill_attention(q, k2, v2, pos, q_block=32)
    np.testing.assert_allclose(out1, out2, rtol=0, atol=0)


# ----------------------------------------------------------------- decode

@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    heads=st.sampled_from([1, 4]),
    dh=st.sampled_from([32, 64]),
    s=st.sampled_from([64, 128, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, heads, dh, s, seed):
    rng = np.random.default_rng(seed)
    q = rand(seed, (b, heads, dh))
    k = rand(seed + 1, (b, s, heads, dh))
    v = rand(seed + 2, (b, s, heads, dh))
    lens = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, lens)
    exp = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, exp, **TOL)


def test_decode_attention_len1_returns_v0():
    q = rand(0, (2, 2, 32))
    k = rand(1, (2, 64, 2, 32))
    v = rand(2, (2, 64, 2, 32))
    lens = jnp.array([1, 1], jnp.int32)
    out = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(out, v[:, 0], **TOL)


def test_decode_attention_ignores_rows_beyond_len():
    q = rand(0, (2, 2, 32))
    k = rand(1, (2, 64, 2, 32))
    v = rand(2, (2, 64, 2, 32))
    lens = jnp.array([10, 32], jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    out2 = decode_attention(q, k.at[:, 40:].set(7.0), v.at[:, 40:].set(-7.0), lens)
    np.testing.assert_allclose(out1, out2, rtol=0, atol=0)


# -------------------------------------------------------------------- moe

@settings(max_examples=15, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    d=st.sampled_from([16, 64]),
    e=st.sampled_from([1, 4, 8]),
    f=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_gemm_matches_ref(n_blocks, d, e, f, seed):
    n = 64 * n_blocks
    x = rand(seed, (n, d))
    w1 = rand(seed + 1, (e, d, f)) / np.sqrt(d)
    w2 = rand(seed + 2, (e, f, d)) / np.sqrt(f)
    out = moe_expert_gemm(x, w1, w2)
    exp = ref.moe_expert_gemm_ref(x, w1, w2)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


def test_moe_full_ffn_ref_consistency():
    """moe_ffn_ref must equal a hand-rolled top-k loop."""
    x = rand(0, (8, 16))
    gate = rand(1, (16, 4))
    w1 = rand(2, (4, 16, 32)) / 4
    w2 = rand(3, (4, 32, 16)) / 4
    got = ref.moe_ffn_ref(x, gate, w1, w2, top_k=2)
    logits = np.asarray(x @ gate)
    expert = np.asarray(ref.moe_expert_gemm_ref(x, w1, w2))
    want = np.zeros_like(np.asarray(x))
    for i in range(x.shape[0]):
        idx = np.argsort(-logits[i])[:2]
        g = np.exp(logits[i][idx] - logits[i][idx].max())
        g = g / g.sum()
        for j, e_id in enumerate(idx):
            want[i] += g[j] * expert[e_id, i]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- rope

def test_rope_preserves_norm_and_relativity():
    x = rand(0, (8, 2, 32))
    pos = jnp.arange(8)
    y = ref.rope_ref(x, pos)
    # Norm preservation (rotation).
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5, atol=1e-5
    )
    # Relative property: dot(q_m, k_n) depends only on m - n.
    q = rand(1, (1, 1, 32))
    k = rand(2, (1, 1, 32))
    def dot_at(m, n):
        qm = ref.rope_ref(q, jnp.array([m]))
        kn = ref.rope_ref(k, jnp.array([n]))
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
