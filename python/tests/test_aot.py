"""AOT path smoke tests: lowering produces parseable HLO text with the
expected entry signature, and the weights manifest is exact."""

import json
import os

import pytest

from compile import aot, model
from compile.config import ModelConfig

TINY = ModelConfig(n_layers=1, max_seq=128)


def test_lower_prefill_hlo_text_shape():
    n_params = len(model.param_spec(TINY))
    text = aot.lower_prefill(TINY, 64, n_params)
    assert text.startswith("HloModule")
    # Entry must take the caches and return the 3-tuple.
    assert "f32[1,128,4,64]" in text          # [L, S, H, Dh]
    assert "s32[64]" in text                  # tokens
    assert "->(f32[64,512]" in text           # per-position logits first
    # The xla_extension-0.5.1-incompatible `topk(...)` op must be absent
    # (we lower top-k as iterative argmax).
    assert " topk(" not in text


def test_lower_decode_hlo_text_shape():
    n_params = len(model.param_spec(TINY))
    text = aot.lower_decode(TINY, 2, n_params)
    assert text.startswith("HloModule")
    assert "f32[1,2,128,4,64]" in text        # [L, B, S, H, Dh]
    assert "->(f32[2,512]" in text            # batched logits
    assert " topk(" not in text


def test_weights_manifest_is_exact(tmp_path):
    manifest, total = aot.write_weights(TINY, str(tmp_path), seed=0)
    blob = (tmp_path / "weights.bin").read_bytes()
    assert len(blob) == total * 4
    # Offsets tile the blob exactly, in order.
    expected = 0
    for entry, (name, shape) in zip(manifest, model.param_spec(TINY)):
        assert entry["name"] == name
        assert entry["offset"] == expected
        n = 1
        for d in shape:
            n *= d
        expected += n
    assert expected == total


def test_full_artifact_dir(tmp_path):
    rc = aot.main(["--out", str(tmp_path), "--layers", "1", "--max-seq", "128"])
    assert rc is None or rc == 0
    meta = json.loads((tmp_path / "model_meta.json").read_text())
    assert meta["model"]["n_layers"] == 1
    files = {v["file"] for v in meta["variants"]}
    for f in files:
        assert (tmp_path / f).exists(), f
    assert os.path.getsize(tmp_path / "weights.bin") == meta["weights"]["total_f32"] * 4
