"""Model/topology configuration for the nano-MoE serving model.

This is the L2/L1 stand-in for DeepSeek-V3: a small Mixture-of-Experts
transformer with the same *structural* properties the paper's scheduler
cares about — DP-replicated attention, expert FFNs behind a shared routing
step, chunked prefill over a KV cache, and batched single-token decode.
Sizes are chosen so interpret-mode Pallas on CPU stays fast while the
AOT artifacts remain realistic to serve.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """nano-MoE hyperparameters (defaults ≈ 8.5M parameters)."""

    vocab: int = 512          # byte-pair-free: raw bytes + specials
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 64          # n_heads * d_head == d_model
    n_experts: int = 8
    top_k: int = 2
    d_ff: int = 512           # per-expert hidden dim
    d_shared_ff: int = 512    # shared-expert hidden dim
    max_seq: int = 512        # KV capacity per sequence
    rope_base: float = 10000.0

    # AOT variant axes: prefill chunk sizes and decode batch sizes.
    prefill_chunks: tuple = (64, 128)
    decode_batches: tuple = (1, 4, 8)

    def n_params(self) -> int:
        """Approximate parameter count."""
        d, e = self.d_model, self.n_experts
        attn = 4 * d * d
        moe = e * 2 * d * self.d_ff + d * e  # experts + router
        shared = 2 * d * self.d_shared_ff
        per_layer = attn + moe + shared + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d


DEFAULT = ModelConfig()
