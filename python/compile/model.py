"""L2: the nano-MoE transformer in JAX, calling the L1 Pallas kernels.

Two entry points are AOT-lowered per variant (see aot.py):

* ``prefill_chunk`` — process one chunk of a single sequence's prompt,
  writing K/V into the cache at positions ``pos..pos+chunk-1`` and
  returning the logits of the chunk's last token.
* ``decode_step``   — one synchronized autoregressive step for a batch of
  sequences, appending one K/V row per sequence.

A ``*_reference`` twin of each, built purely from kernels/ref.py, provides
the end-to-end oracle for pytest.

Parameters are a flat list of arrays in the order given by
``param_spec()`` so the Rust runtime can feed PJRT buffers positionally.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ref
from .kernels.decode_attn import decode_attention
from .kernels.flash_prefill import causal_prefill_attention
from .kernels.moe_gemm import moe_expert_gemm


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list — the ABI between aot.py and Rust."""
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    e, f, fs = cfg.n_experts, cfg.d_ff, cfg.d_shared_ff
    spec = [("embed", (cfg.vocab, d))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "norm_attn", (d,)),
            (p + "wq", (d, h * dh)),
            (p + "wk", (d, h * dh)),
            (p + "wv", (d, h * dh)),
            (p + "wo", (h * dh, d)),
            (p + "norm_ffn", (d,)),
            (p + "router", (d, e)),
            (p + "w1", (e, d, f)),
            (p + "w2", (e, f, d)),
            (p + "shared_w1", (d, fs)),
            (p + "shared_w2", (fs, d)),
        ]
    spec += [("norm_out", (d,)), ("lm_head", (d, cfg.vocab))]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic scaled-normal init, returned as the flat list."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if "norm" in name:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / jnp.sqrt(jnp.array(fan_in, jnp.float32))
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _unflatten(cfg: ModelConfig, flat):
    """flat list -> (embed, [per-layer dicts], norm_out, lm_head)."""
    names = [n for n, _ in param_spec(cfg)]
    by_name = dict(zip(names, flat))
    layers = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        layers.append({k: by_name[p + k] for k in (
            "norm_attn", "wq", "wk", "wv", "wo",
            "norm_ffn", "router", "w1", "w2", "shared_w1", "shared_w2",
        )})
    return by_name["embed"], layers, by_name["norm_out"], by_name["lm_head"]


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _top_k_manual(logits, k):
    """Iterative-argmax top-k.

    Functionally identical to jax.lax.top_k for distinct values but lowers
    to plain reduce/select HLO — the `topk` instruction jax emits carries a
    `largest=` attribute that xla_extension 0.5.1's HLO parser rejects.
    """
    vals, idxs = [], []
    x = logits
    rows = jnp.arange(logits.shape[0])
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)
        v = x[rows, i]
        vals.append(v)
        idxs.append(i)
        x = x.at[rows, i].set(-jnp.inf)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _moe_block(x, lp, cfg, kernels: bool):
    """Router + top-k combine over expert outputs + shared expert."""
    logits = x @ lp["router"]
    top_vals, top_idx = _top_k_manual(logits, cfg.top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    dense = jnp.zeros_like(logits)
    rows = jnp.arange(x.shape[0])[:, None]
    dense = dense.at[rows, top_idx].set(gates)
    if kernels:
        expert_out = moe_expert_gemm(x, lp["w1"], lp["w2"], n_block=min(64, x.shape[0]))
    else:
        expert_out = ref.moe_expert_gemm_ref(x, lp["w1"], lp["w2"])
    mixed = jnp.einsum("end,ne->nd", expert_out, dense)
    shared = ref.gelu(x @ lp["shared_w1"]) @ lp["shared_w2"]
    return mixed + shared


def _qkv(x, lp, cfg, positions):
    h, dh = cfg.n_heads, cfg.d_head
    t = x.shape[0]
    q = (x @ lp["wq"]).reshape(t, h, dh)
    k = (x @ lp["wk"]).reshape(t, h, dh)
    v = (x @ lp["wv"]).reshape(t, h, dh)
    q = ref.rope_ref(q, positions, cfg.rope_base)
    k = ref.rope_ref(k, positions, cfg.rope_base)
    return q, k, v


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------

def prefill_chunk(cfg: ModelConfig, flat_params, tokens, k_caches, v_caches, pos,
                  kernels: bool = True):
    """Process one prompt chunk of a single sequence.

    Args:
      tokens: [chunk] int32 token ids.
      k_caches, v_caches: [L, S, H, Dh] per-layer KV caches.
      pos: int32 scalar — absolute position of tokens[0].

    Returns:
      (logits [chunk, vocab], new k_caches, new v_caches)
      Per-position logits so a padded final chunk can read the last *real*
      token's row.
    """
    embed, layers, norm_out, lm_head = _unflatten(cfg, flat_params)
    chunk = tokens.shape[0]
    positions = pos + jnp.arange(chunk)
    x = embed[tokens]
    new_k, new_v = [], []
    for li, lp in enumerate(layers):
        xn = ref.rmsnorm_ref(x, lp["norm_attn"])
        q, k, v = _qkv(xn, lp, cfg, positions)
        kc = jax.lax.dynamic_update_slice(k_caches[li], k, (pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_caches[li], v, (pos, 0, 0))
        if kernels:
            attn = causal_prefill_attention(q, kc, vc, pos, q_block=min(64, chunk))
        else:
            attn = ref.causal_prefill_attention_ref(q, kc, vc, pos)
        x = x + attn.reshape(chunk, -1) @ lp["wo"]
        xn = ref.rmsnorm_ref(x, lp["norm_ffn"])
        x = x + _moe_block(xn, lp, cfg, kernels)
        new_k.append(kc)
        new_v.append(vc)
    x = ref.rmsnorm_ref(x, norm_out)
    logits = x @ lm_head
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, flat_params, tokens, k_caches, v_caches, lens,
                kernels: bool = True):
    """One autoregressive step for a batch.

    Args:
      tokens: [B] int32 current token per sequence.
      k_caches, v_caches: [L, B, S, H, Dh].
      lens: [B] int32 — valid KV length per sequence *before* this step.

    Returns:
      (logits [B, vocab], new k_caches, new v_caches)
      The new token's K/V is written at position lens (lens+1 valid after).
    """
    embed, layers, norm_out, lm_head = _unflatten(cfg, flat_params)
    b = tokens.shape[0]
    x = embed[tokens]                                  # [B, d]
    new_k, new_v = [], []
    for li, lp in enumerate(layers):
        xn = ref.rmsnorm_ref(x, lp["norm_attn"])
        h, dh = cfg.n_heads, cfg.d_head
        q = (xn @ lp["wq"]).reshape(b, h, dh)
        k = (xn @ lp["wk"]).reshape(b, h, dh)
        v = (xn @ lp["wv"]).reshape(b, h, dh)
        q = ref.rope_ref(q, lens, cfg.rope_base)
        k = ref.rope_ref(k, lens, cfg.rope_base)
        # Scatter each sequence's new K/V row at its own length.
        def put(cache, row):
            def one(c, r, n):
                return jax.lax.dynamic_update_slice(c, r[None], (n, 0, 0))
            return jax.vmap(one)(cache, row, lens)
        kc = put(k_caches[li], k)
        vc = put(v_caches[li], v)
        if kernels:
            attn = decode_attention(q, kc, vc, lens + 1)
        else:
            attn = ref.decode_attention_ref(q, kc, vc, lens + 1)
        x = x + attn.reshape(b, -1) @ lp["wo"]
        xn = ref.rmsnorm_ref(x, lp["norm_ffn"])
        x = x + _moe_block(xn, lp, cfg, kernels)
        new_k.append(kc)
        new_v.append(vc)
    x = ref.rmsnorm_ref(x, norm_out)
    logits = x @ lm_head
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# --------------------------------------------------------------------------
# Reference twins (pure ref.py; the pytest oracle)
# --------------------------------------------------------------------------

def prefill_chunk_reference(cfg, flat_params, tokens, k_caches, v_caches, pos):
    return prefill_chunk(cfg, flat_params, tokens, k_caches, v_caches, pos, kernels=False)


def decode_step_reference(cfg, flat_params, tokens, k_caches, v_caches, lens):
    return decode_step(cfg, flat_params, tokens, k_caches, v_caches, lens, kernels=False)


def empty_prefill_cache(cfg: ModelConfig):
    """[L, S, H, Dh] zeroed single-sequence cache."""
    return jnp.zeros(
        (cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.d_head), jnp.float32
    )


def empty_decode_cache(cfg: ModelConfig, batch: int):
    """[L, B, S, H, Dh] zeroed batched cache."""
    return jnp.zeros(
        (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.d_head), jnp.float32
    )
