"""L1 Pallas kernel: batched single-token decode attention.

Grid walks (sequence, head); each program loads its sequence's whole KV
stripe for one head into VMEM (S×Dh f32 = 128 KiB at S=512, Dh=64 — small
against a 16 MiB budget) and does a masked softmax-weighted reduction.
Decode is memory-bound: the schedule is one streaming read of K and V per
program, which is exactly the HBM→VMEM traffic a TPU decode kernel is
optimizing; no online-softmax needed at these cache lengths.

interpret=True — see flash_prefill.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, s):
    """One (sequence, head) program."""
    n = len_ref[0]
    q = q_ref[...]  # [dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(dh, dtype=jnp.float32))
    k = k_ref[...]  # [S, dh]
    v = v_ref[...]
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [S]
    mask = jax.lax.iota(jnp.int32, s) < n
    scores = jnp.where(mask, scores, NEG_INF)
    m = scores.max()
    p = jnp.exp(scores - m)
    p = p / p.sum()
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lens):
    """Batched decode attention (Pallas, interpret mode).

    Args:
      q: [B, H, Dh] — current token per sequence.
      k_cache, v_cache: [B, S, H, Dh].
      lens: [B] int32 valid KV lengths (current token included).

    Returns:
      [B, H, Dh].
    """
    b, h, dh = q.shape
    s = k_cache.shape[1]
    kernel = functools.partial(_decode_kernel, s=s)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),                  # lens
            pl.BlockSpec((None, None, dh), lambda bi, hi: (bi, hi, 0)),  # q
            pl.BlockSpec((None, s, None, dh), lambda bi, hi: (bi, 0, hi, 0)),  # k
            pl.BlockSpec((None, s, None, dh), lambda bi, hi: (bi, 0, hi, 0)),  # v
        ],
        out_specs=pl.BlockSpec((None, None, dh), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=True,
    )(lens.astype(jnp.int32), q, k_cache, v_cache)
