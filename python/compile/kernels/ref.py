"""Pure-jnp oracles for every Pallas kernel (L1 correctness ground truth).

pytest checks each kernel against these references over randomized shapes
(hypothesis sweeps); the L2 model also exposes a reference forward built
only from these, used to validate the kernelized model end-to-end.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gelu(x):
    """tanh-approx GELU (matches jax.nn.gelu(approximate=True))."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def causal_prefill_attention_ref(q, k_cache, v_cache, pos):
    """Chunked causal attention over a KV cache.

    Args:
      q: [chunk, H, Dh] queries for absolute positions pos..pos+chunk-1.
      k_cache, v_cache: [S, H, Dh]; rows < pos+chunk are valid (the
        current chunk's K/V already written).
      pos: int32 scalar — absolute position of the chunk's first token.

    Returns:
      [chunk, H, Dh] attention outputs.
    """
    chunk, _, dh = q.shape
    s = k_cache.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.array(dh, dtype=q.dtype))
    scores = jnp.einsum("qhd,khd->hqk", q, k_cache) * scale
    q_pos = pos + jnp.arange(chunk)[:, None]            # [chunk, 1]
    k_pos = jnp.arange(s)[None, :]                      # [1, S]
    mask = k_pos <= q_pos                               # [chunk, S]
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, v_cache)


def decode_attention_ref(q, k_cache, v_cache, lens):
    """Batched single-token decode attention.

    Args:
      q: [B, H, Dh] — one query token per sequence.
      k_cache, v_cache: [B, S, H, Dh].
      lens: [B] int32 — valid KV length per sequence (the current token's
        K/V is already written at position lens-1).

    Returns:
      [B, H, Dh].
    """
    _, _, dh = q.shape
    s = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.array(dh, dtype=q.dtype))
    scores = jnp.einsum("bhd,bkhd->bhk", q, k_cache) * scale
    mask = jnp.arange(s)[None, :] < lens[:, None]       # [B, S]
    scores = jnp.where(mask[:, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v_cache)


def moe_expert_gemm_ref(x, w1, w2):
    """Per-expert two-layer FFN applied densely to all tokens.

    Args:
      x: [N, d] tokens.
      w1: [E, d, f]; w2: [E, f, d].

    Returns:
      [E, N, d] — every expert's output for every token (the dense-MoE
      formulation; gating/combining happens outside).
    """
    hidden = jnp.einsum("nd,edf->enf", x, w1)
    return jnp.einsum("enf,efd->end", gelu(hidden), w2)


def moe_ffn_ref(x, gate_w, w1, w2, top_k):
    """Full top-k MoE feed-forward (router + experts + combine)."""
    logits = x @ gate_w                                 # [N, E]
    top_vals, top_idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)           # softmax over top-k
    dense = jnp.zeros_like(logits)
    rows = jnp.arange(logits.shape[0])[:, None]
    dense = dense.at[rows, top_idx].set(gates)          # [N, E]
    expert_out = moe_expert_gemm_ref(x, w1, w2)         # [E, N, d]
    return jnp.einsum("end,ne->nd", expert_out, dense)


def rmsnorm_ref(x, w, eps=1e-6):
    """RMSNorm over the last axis."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_ref(x, positions, base=10000.0):
    """Rotary position embedding.

    Args:
      x: [..., T, H, Dh] with Dh even.
      positions: [..., T] int32 absolute positions (broadcastable).
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)
