"""L1 Pallas kernel: blocked causal prefill attention over a KV cache.

TPU-minded structure (see DESIGN.md §Hardware-Adaptation): the grid walks
(head, q-block); each program streams the KV cache through VMEM in
`KV_BLOCK`-sized tiles, maintaining an online-softmax accumulator — the
flash-attention schedule expressed with BlockSpec instead of CUDA
threadblocks. Must run with interpret=True on CPU (real-TPU lowering emits
a Mosaic custom-call the CPU PJRT client cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# KV tile streamed through VMEM per iteration. 128 lanes wide — MXU/VPU
# native tiling; at Dh=64 a (128, 64) f32 tile is 32 KiB, so q-tile + 2 kv
# tiles + accumulators stay well inside a 16 MiB VMEM budget.
KV_BLOCK = 128


def _attention_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, q_block, kv_len):
    """One (head, q-block) program: online-softmax over KV tiles."""
    pos = pos_ref[0]
    qi = pl.program_id(1)
    q = q_ref[...]  # [q_block, dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(dh, dtype=jnp.float32))
    q_pos = pos + qi * q_block + jax.lax.iota(jnp.int32, q_block)  # [q_block]

    def body(carry, kv_i):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kv_i * KV_BLOCK, KV_BLOCK), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kv_i * KV_BLOCK, KV_BLOCK), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        k_pos = kv_i * KV_BLOCK + jax.lax.iota(jnp.int32, KV_BLOCK)
        mask = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    n_kv = kv_len // KV_BLOCK
    init = (
        jnp.full((q_block,), NEG_INF, dtype=jnp.float32),
        jnp.zeros((q_block,), dtype=jnp.float32),
        jnp.zeros((q_block, dh), dtype=jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_kv))
    # Fully-masked rows (can't happen causally: j == i always valid) guard.
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def causal_prefill_attention(q, k_cache, v_cache, pos, q_block=64):
    """Blocked causal attention over a KV cache (Pallas, interpret mode).

    Args:
      q: [chunk, H, Dh] queries at absolute positions pos..pos+chunk-1.
      k_cache, v_cache: [S, H, Dh], S a multiple of KV_BLOCK.
      pos: int32 scalar.
      q_block: q-tile size (chunk must be a multiple).

    Returns:
      [chunk, H, Dh].
    """
    chunk, h, dh = q.shape
    s = k_cache.shape[0]
    assert chunk % q_block == 0, (chunk, q_block)
    assert s % KV_BLOCK == 0, (s, KV_BLOCK)
    pos_arr = jnp.reshape(pos.astype(jnp.int32), (1,))
    kernel = functools.partial(_attention_kernel, q_block=q_block, kv_len=s)
    # Layout: heads on the leading grid axis; q/k/v sliced per head.
    out = pl.pallas_call(
        kernel,
        grid=(h, chunk // q_block),
        in_specs=[
            pl.BlockSpec((1,), lambda hi, qi: (0,)),                     # pos
            pl.BlockSpec((q_block, None, dh), lambda hi, qi: (qi, hi, 0)),  # q
            pl.BlockSpec((s, None, dh), lambda hi, qi: (0, hi, 0)),      # k
            pl.BlockSpec((s, None, dh), lambda hi, qi: (0, hi, 0)),      # v
        ],
        out_specs=pl.BlockSpec((q_block, None, dh), lambda hi, qi: (qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((chunk, h, dh), q.dtype),
        interpret=True,
    )(pos_arr, q, k_cache, v_cache)
    return out
