"""L1 Pallas kernel: grouped expert GEMM for the MoE FFN.

Grid walks (expert, token-block); each program computes one expert's
two-layer FFN for one tile of tokens: an (n_block×d)·(d×f) matmul, GELU,
then (n_block×f)·(f×d) — MXU-shaped tiles with the weights resident in
VMEM for the duration of the token loop (the dense-MoE schedule; gating
and the weighted combine are cheap VPU work left to XLA in L2).

interpret=True — see flash_prefill.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _moe_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One (expert, token-block) program."""
    x = x_ref[...]          # [n_block, d]
    w1 = w1_ref[...]        # [d, f]
    w2 = w2_ref[...]        # [f, d]
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    h = _gelu(h)
    o_ref[...] = jnp.dot(h, w2, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def moe_expert_gemm(x, w1, w2, n_block=64):
    """Dense per-expert FFN outputs (Pallas, interpret mode).

    Args:
      x: [N, d] tokens (N a multiple of n_block).
      w1: [E, d, f]; w2: [E, f, d].

    Returns:
      [E, N, d] — expert e's output for every token (combine outside).
    """
    n, d = x.shape
    e, _, f = w1.shape
    assert n % n_block == 0, (n, n_block)
    return pl.pallas_call(
        _moe_kernel,
        grid=(e, n // n_block),
        in_specs=[
            pl.BlockSpec((n_block, d), lambda ei, ni: (ni, 0)),        # x
            pl.BlockSpec((None, d, f), lambda ei, ni: (ei, 0, 0)),     # w1
            pl.BlockSpec((None, f, d), lambda ei, ni: (ei, 0, 0)),     # w2
        ],
        out_specs=pl.BlockSpec((None, n_block, d), lambda ei, ni: (ei, ni, 0)),
        out_shape=jax.ShapeDtypeStruct((e, n, d), x.dtype),
        interpret=True,
    )(x, w1, w2)
