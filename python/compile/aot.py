"""AOT path: lower the L2 model (with L1 Pallas kernels) to HLO text.

Usage (from python/):  python -m compile.aot --out ../artifacts

Emits, per variant:
  prefill_c{chunk}.hlo.txt    — prefill_chunk entry
  decode_b{batch}.hlo.txt     — decode_step entry
plus:
  weights.bin                 — all parameters, little-endian f32, in
                                param_spec order
  model_meta.json             — config, parameter manifest (name/shape/
                                offset), variant ABI (argument order and
                                shapes), output arity

Interchange is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that the xla crate's XLA (xla_extension
0.5.1) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import DEFAULT, ModelConfig
from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: ModelConfig, chunk: int, n_params: int) -> str:
    """Lower prefill_chunk for a fixed chunk size."""

    def fn(*args):
        params = list(args[:n_params])
        tokens, kc, vc, pos = args[n_params:]
        return model.prefill_chunk(cfg, params, tokens, kc, vc, pos)

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.param_spec(cfg)]
    shapes += [
        jax.ShapeDtypeStruct((chunk,), jnp.int32),
        jax.ShapeDtypeStruct(
            (cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.d_head), jnp.float32
        ),
        jax.ShapeDtypeStruct(
            (cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.d_head), jnp.float32
        ),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*shapes))


def lower_decode(cfg: ModelConfig, batch: int, n_params: int) -> str:
    """Lower decode_step for a fixed batch size."""

    def fn(*args):
        params = list(args[:n_params])
        tokens, kc, vc, lens = args[n_params:]
        return model.decode_step(cfg, params, tokens, kc, vc, lens)

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.param_spec(cfg)]
    shapes += [
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.d_head), jnp.float32
        ),
        jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.d_head), jnp.float32
        ),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*shapes))


def write_weights(cfg: ModelConfig, out_dir: str, seed: int):
    """weights.bin + manifest entries (name, shape, offset in f32 elems)."""
    params = model.init_params(cfg, seed)
    manifest = []
    offset = 0
    blob = bytearray()
    for (name, shape), arr in zip(model.param_spec(cfg), params):
        a = np.asarray(arr, dtype="<f4")
        manifest.append({"name": name, "shape": list(shape), "offset": offset})
        offset += int(a.size)
        blob += a.tobytes()
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        f.write(bytes(blob))
    return manifest, offset


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--layers", type=int, default=DEFAULT.n_layers)
    ap.add_argument("--max-seq", type=int, default=DEFAULT.max_seq)
    args = ap.parse_args(argv)

    cfg = ModelConfig(n_layers=args.layers, max_seq=args.max_seq)
    os.makedirs(args.out, exist_ok=True)
    n_params = len(model.param_spec(cfg))

    variants = []
    for chunk in cfg.prefill_chunks:
        name = f"prefill_c{chunk}"
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = lower_prefill(cfg, chunk, n_params)
        with open(path, "w") as f:
            f.write(text)
        variants.append({
            "name": name, "kind": "prefill", "chunk": chunk,
            "file": f"{name}.hlo.txt",
        })
        print(f"wrote {path} ({len(text)} chars)")
    for batch in cfg.decode_batches:
        name = f"decode_b{batch}"
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = lower_decode(cfg, batch, n_params)
        with open(path, "w") as f:
            f.write(text)
        variants.append({
            "name": name, "kind": "decode", "batch": batch,
            "file": f"{name}.hlo.txt",
        })
        print(f"wrote {path} ({len(text)} chars)")

    manifest, total = write_weights(cfg, args.out, args.seed)
    meta = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head, "n_experts": cfg.n_experts,
            "top_k": cfg.top_k, "d_ff": cfg.d_ff,
            "d_shared_ff": cfg.d_shared_ff, "max_seq": cfg.max_seq,
        },
        "weights": {"file": "weights.bin", "total_f32": total, "params": manifest},
        "variants": variants,
        "abi": {
            "order": "params... , tokens, k_caches, v_caches, pos_or_lens",
            "outputs": "(logits, k_caches, v_caches) as a 3-tuple",
        },
        "seed": args.seed,
    }
    meta_path = os.path.join(args.out, "model_meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}; {total} f32 weights")


if __name__ == "__main__":
    sys.exit(main())
