//! End-to-end driver (the repository's headline validation): serve real
//! batched requests through the full three-layer stack — SBS scheduler
//! (L3 rust) → PJRT engines executing the AOT-compiled nano-MoE (L2 jax)
//! with Pallas kernels (L1) — and report latency/throughput, comparing
//! the staggered scheduler against immediate dispatch on the same jobs.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_cluster`
//! (SBS_E2E_REQUESTS / SBS_E2E_MAXNEW env knobs; defaults 8 / 8.)

use sbs::cluster::workers::{EngineSpec, Job, RealCluster, RealClusterConfig, RealSchedMode};
use sbs::engine::tokenizer;
use sbs::metrics::{DecodePoolStats, ServingReport};
use sbs::runtime::artifacts_dir;
use sbs::scheduler::baseline::ImmediatePolicy;

fn env_or(key: &str, default: u32) -> u32 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Remote decode shard addresses from `SBS_E2E_SHARDS` (comma-separated
/// `sbs worker --decode` listeners), joined to the pool when set.
fn env_shards() -> Vec<String> {
    std::env::var("SBS_E2E_SHARDS")
        .map(|s| sbs::transport::parse_shard_list(&s))
        .unwrap_or_default()
}

/// Remote prefill shard addresses from `SBS_E2E_PREFILL_SHARDS`
/// (comma-separated `sbs worker --prefill` listeners).
fn env_prefill_shards() -> Vec<String> {
    std::env::var("SBS_E2E_PREFILL_SHARDS")
        .map(|s| sbs::transport::parse_shard_list(&s))
        .unwrap_or_default()
}

fn run_mode(
    mode: RealSchedMode,
    n: u32,
    max_new: u32,
) -> anyhow::Result<(ServingReport, DecodePoolStats)> {
    let cfg = RealClusterConfig {
        n_prefill: 2,
        decode_batch: 4,
        mode,
        engine: EngineSpec::Pjrt {
            artifacts: artifacts_dir(),
        },
        remote_decode: env_shards(),
        remote_prefill: env_prefill_shards(),
        // Both comparison runs share one shard set: disconnect on drain
        // instead of stopping the worker processes between runs.
        stop_shards_on_drain: false,
        ..Default::default()
    };
    let cluster = RealCluster::start(cfg)?;
    let handle = cluster.handle();
    for i in 0..n {
        let prompt = tokenizer::encode(&format!(
            "[session {i}] Summarize the effect of staggered batch \
             scheduling on time-to-first-token for request number {i} \
             in a production DP+EP cluster with chunked prefill."
        ));
        cluster.submit(Job {
            id: i as u64,
            prompt,
            max_new,
        });
        // Poisson-ish spacing so the batching window has something to do.
        std::thread::sleep(std::time::Duration::from_millis(150));
    }
    let (_completions, report) = cluster.finish()?;
    Ok((report, handle.decode_stats()))
}

/// Render both pools per unit, shard deaths included: a unit whose
/// transport died mid-run shows `DEAD`, not a silently shrunk pool.
fn render_pool(stats: &DecodePoolStats) -> String {
    let mut s = format!(
        "decode pool [{}]: {}/{} units alive, imbalance {:.2}\n",
        stats.policy,
        stats.units_alive(),
        stats.units.len(),
        stats.imbalance()
    );
    for u in &stats.units {
        let rtt = u
            .rtt_ms
            .map(|ms| format!(" rtt={ms:.2}ms"))
            .unwrap_or_default();
        s.push_str(&format!(
            "  {} via {}{}: {} — placed={} active={} busy={:.2}s\n",
            u.unit,
            u.transport,
            rtt,
            if u.alive { "alive" } else { "DEAD" },
            u.placed,
            u.active,
            u.seq_seconds,
        ));
    }
    if stats.kv_wire.raw_bytes > 0 || stats.kv_wire.relay_raw_bytes > 0 {
        s.push_str(&format!(
            "kv wire [{}]: shard-inbound {} B coded / {} B raw, scheduler-relay {} B coded\n",
            stats.kv_wire.codec,
            stats.kv_wire.wire_bytes,
            stats.kv_wire.raw_bytes,
            stats.kv_wire.relay_wire_bytes,
        ));
    }
    s.push_str(&format!(
        "prefill pool: {}/{} instances alive\n",
        stats.prefill_units_alive(),
        stats.prefill.len()
    ));
    for p in &stats.prefill {
        let rtt = p
            .rtt_ms
            .map(|ms| format!(" rtt={ms:.2}ms"))
            .unwrap_or_default();
        s.push_str(&format!(
            "  {} via {}{}: {} — dispatched={}\n",
            p.unit,
            p.transport,
            rtt,
            if p.alive { "alive" } else { "DEAD" },
            p.dispatched,
        ));
    }
    s
}

fn main() -> anyhow::Result<()> {
    sbs::logging::init(log::LevelFilter::Warn);
    if !artifacts_dir().join("model_meta.json").exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return Ok(());
    }
    let n = env_or("SBS_E2E_REQUESTS", 8);
    let max_new = env_or("SBS_E2E_MAXNEW", 8);

    println!("=== staggered batch scheduling (SBS) ===");
    let (sbs_report, sbs_pool) =
        run_mode(RealSchedMode::Staggered(Default::default()), n, max_new)?;
    println!("{}", sbs_report.render());
    println!("{}", render_pool(&sbs_pool));

    println!("\n=== immediate dispatch (round-robin baseline) ===");
    let (base_report, base_pool) = run_mode(
        RealSchedMode::Immediate(ImmediatePolicy::RoundRobin),
        n,
        max_new,
    )?;
    println!("{}", base_report.render());
    println!("{}", render_pool(&base_pool));

    let tb = base_report.ttft.mean_ms();
    let ts = sbs_report.ttft.mean_ms();
    if tb > 0.0 {
        println!(
            "\nmean TTFT: baseline {tb:.0} ms vs SBS {ts:.0} ms ({:+.1}%)",
            (ts - tb) / tb * 100.0
        );
    }
    println!(
        "(real PJRT execution on CPU with interpret-mode Pallas. At this demo scale —\n          a handful of requests on 2 instances — the SBS-vs-baseline delta is run noise;\n          the cluster-scale comparison lives in the DES: see EXPERIMENTS.md.)"
    );
    Ok(())
}
