//! Quickstart: load the AOT artifacts, run a chunked prefill and a few
//! decode steps directly against the PJRT runtime — the smallest possible
//! tour of the public API. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example quickstart`

use sbs::engine::sampler::Sampling;
use sbs::engine::{tokenizer, MiniEngine};
use sbs::runtime::{artifacts_dir, Runtime};
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    sbs::logging::init(log::LevelFilter::Info);
    let dir = artifacts_dir();
    if !dir.join("model_meta.json").exists() {
        eprintln!("no artifacts at {} — run `make artifacts` first", dir.display());
        return Ok(());
    }

    println!("loading runtime (compiling {} variants)...", 5);
    let t0 = Instant::now();
    let rt = Arc::new(Runtime::load(&dir)?);
    println!(
        "loaded in {:.1}s: prefill chunks {:?}, decode batches {:?}, vocab {}",
        t0.elapsed().as_secs_f64(),
        rt.prefill_chunks(),
        rt.decode_batches(),
        rt.meta.model.vocab
    );

    let mut engine = MiniEngine::new(rt, 4, Sampling::Greedy, 42)?;
    let prompt = tokenizer::encode(
        "Staggered batch scheduling buffers requests to form optimal \
         execution batches, eliminating device-side queuing.",
    );
    println!("\nprompt: {} tokens", prompt.len());

    // Chunked prefill (the gated, non-preemptive pass).
    let t0 = Instant::now();
    let pre = engine.prefill(&prompt)?;
    println!(
        "prefill: {} passes, {:.0} ms exec → first token {} (TTFT {:.0} ms)",
        pre.passes,
        pre.exec_time * 1e3,
        pre.first_token,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // Batched decode.
    engine.admit(&pre, 12, 0)?;
    let mut generated = vec![pre.first_token];
    let t0 = Instant::now();
    while engine.active() > 0 {
        let (emissions, _) = engine.step()?;
        for e in emissions {
            generated.push(e.token);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "decode: {} tokens in {:.1}s ({:.1} tok/s)",
        generated.len() - 1,
        dt,
        (generated.len() - 1) as f64 / dt
    );
    println!("token ids: {generated:?}");
    println!("text: {:?}", tokenizer::decode(&generated));
    println!("\n(random-init weights — the text is noise; the machinery is the point)");
    Ok(())
}
