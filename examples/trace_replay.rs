//! Trace workflow: generate a workload trace, persist it as JSONL, replay
//! it bit-exactly through the cluster simulator under every scheduler,
//! and print a comparison table — the "rerun production traffic against a
//! candidate scheduler" loop.
//!
//! Run: `cargo run --release --example trace_replay`

use sbs::cluster::sim::{SchedMode, Simulation};
use sbs::config;
use sbs::scheduler::baseline::ImmediatePolicy;
use sbs::workload::{read_trace, write_trace, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    sbs::logging::init(log::LevelFilter::Warn);
    let dir = std::env::temp_dir().join("sbs_trace_replay");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("trace.jsonl");

    // 1. Record: a 60-second production-like trace at 100 QPS.
    let spec = WorkloadSpec::paper_short(100.0, 60.0, 7);
    let reqs = spec.generate();
    write_trace(&path, &reqs)?;
    println!(
        "recorded {} requests ({:.1} MB) to {}",
        reqs.len(),
        std::fs::metadata(&path)?.len() as f64 / 1e6,
        path.display()
    );

    // 2. Replay under each scheduler.
    println!(
        "\n{:<22} {:>12} {:>12} {:>12} {:>12}",
        "scheduler", "TTFT(ms)", "p99(ms)", "devq(ms)", "chunk util"
    );
    let variants: Vec<(&str, SchedMode)> = vec![
        ("staggered (SBS)", SchedMode::Staggered(Default::default())),
        (
            "round_robin",
            SchedMode::Immediate(ImmediatePolicy::RoundRobin),
        ),
        (
            "least_outstanding",
            SchedMode::Immediate(ImmediatePolicy::LeastOutstanding),
        ),
        (
            "join_shortest_queue",
            SchedMode::Immediate(ImmediatePolicy::JoinShortestQueue),
        ),
    ];
    for (label, mode) in variants {
        let trace = read_trace(&path)?; // bit-exact replay input
        let mut cfg = config::fig6a(1.0, true, 0);
        cfg.mode = mode;
        cfg.workload.duration = 60.0;
        cfg.warmup = 10.0;
        let r = Simulation::run_trace(&cfg, trace);
        println!(
            "{:<22} {:>12.1} {:>12.1} {:>12.1} {:>11.1}%",
            label,
            r.report.ttft.mean_ms(),
            r.report.ttft.percentile_ms(99.0),
            r.report.device_queue.mean_ms(),
            r.report.chunk_util.utilization() * 100.0
        );
    }
    println!("\nsame trace, same engines, different control planes.");
    Ok(())
}
