//! Sweep + compare demo: run a small replicated experiment grid twice —
//! once with the adaptive stagger interval and once degraded to a long
//! static window — and put the two BENCH documents through the same
//! noise-aware comparison the CI bench gate uses. The degraded run should
//! surface as TTFT regressions; the reverse comparison as improvements.
//!
//! Run: `cargo run --release --example sweep_compare`

use sbs::workload::sweep::{self, SweepGrid, SweepModes};

fn main() -> anyhow::Result<()> {
    sbs::logging::init(log::LevelFilter::Warn);
    let grid = SweepGrid {
        scheds: vec!["staggered".into()],
        arrivals: vec!["poisson".into(), "bursty".into()],
        qps: vec![100.0],
        replicas: 3,
        seed: 21,
        duration: 20.0,
        warmup: 5.0,
        ..SweepGrid::default()
    };
    let modes = SweepModes {
        bench_id: "BENCH_EXAMPLE".into(),
        des: true,
        live: None,
    };

    println!("running baseline grid (adaptive stagger interval)...");
    let baseline = sweep::run_sweep(&grid, &modes)?;

    // Same grid, but the interval controller pinned to a 2 s static
    // window: requests sit in formation far longer than Algorithm 1
    // would allow, so TTFT should visibly regress.
    println!("running degraded grid (static 2 s stagger window)...");
    let mut degraded_grid = grid.clone();
    degraded_grid.windows = vec![2.0];
    let degraded = sweep::run_sweep(&degraded_grid, &modes)?;

    // The window is a recorded parameter, so align the documents before
    // comparing: rewrite the degraded params to the baseline's key space.
    let degraded = realign_window(degraded, &baseline);

    for (label, old, new) in [
        ("baseline -> degraded", &baseline, &degraded),
        ("degraded -> baseline", &degraded, &baseline),
    ] {
        let rep = sweep::compare(old, new, 0.25, 3.0)?;
        println!("\n{label}: {} points compared", rep.compared);
        for line in &rep.regressions {
            println!("  REGRESSED {line}");
        }
        for line in &rep.improvements {
            println!("  improved  {line}");
        }
        if rep.regressions.is_empty() && rep.improvements.is_empty() {
            println!("  (no change beyond thresholds)");
        }
    }
    Ok(())
}

/// Copy the baseline's `stagger_window_s` into the degraded document's
/// params so [`sweep::compare`] pairs the grid points up.
fn realign_window(mut doc: sbs::json::Json, baseline: &sbs::json::Json) -> sbs::json::Json {
    use sbs::json::Json;
    let window = baseline
        .get("points")
        .and_then(Json::as_arr)
        .and_then(|pts| pts.first())
        .and_then(|pt| pt.f64_at(&["params", "stagger_window_s"]))
        .unwrap_or(0.0);
    if let Json::Obj(root) = &mut doc {
        if let Some(Json::Arr(points)) = root.get_mut("points") {
            for pt in points {
                if let Json::Obj(p) = pt {
                    if let Some(Json::Obj(params)) = p.get_mut("params") {
                        params.insert("stagger_window_s".into(), Json::from(window));
                    }
                }
            }
        }
    }
    doc
}
