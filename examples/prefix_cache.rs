//! Cache-aware scheduling demo (§4.2.2): a multi-tenant workload where
//! requests share Zipf-popular system prompts. Cache-aware PBAA routes
//! requests to the DP units already holding their prefix KV, cutting
//! effective prefill compute; basic PBAA treats every token as cold.
//!
//! Run: `cargo run --release --example prefix_cache`

use sbs::cluster::sim::{SchedMode, Simulation};
use sbs::config;
use sbs::workload::{LengthDist, PrefixSpec};

fn main() {
    sbs::logging::init(log::LevelFilter::Warn);
    println!("multi-tenant workload: 16 system prompts (Zipf 1.1), 80% participation,");
    println!("prefix 256–1024 tokens of mean-1K prompts, 100 QPS, 3P1D chunk 3K\n");
    println!(
        "{:<16} {:>12} {:>12} {:>14} {:>16}",
        "PBAA mode", "TTFT(ms)", "p99(ms)", "prefill tok/s", "passes (fewer=hit)"
    );
    for (label, cache_aware) in [("basic", false), ("cache-aware", true)] {
        let mut cfg = config::fig6a(1.0, true, 33);
        cfg.workload.duration = 90.0;
        cfg.warmup = 15.0;
        cfg.workload.prefix = Some(PrefixSpec {
            groups: 16,
            zipf_s: 1.1,
            prefix_len: LengthDist::Uniform { lo: 256, hi: 1024 },
            participation: 0.8,
        });
        if let SchedMode::Staggered(sc) = &mut cfg.mode {
            sc.pbaa.cache_aware = cache_aware;
        }
        let r = Simulation::run(&cfg);
        println!(
            "{:<16} {:>12.1} {:>12.1} {:>14.0} {:>16}",
            label,
            r.report.ttft.mean_ms(),
            r.report.ttft.percentile_ms(99.0),
            r.report.throughput.prefill_tps(),
            r.prefill_passes,
        );
    }
    println!("\ncache-aware PBAA computes fewer effective tokens for the same requests:");
    println!("lower prefill tok/s at equal QPS = KV reuse, and TTFT drops accordingly.");
}
